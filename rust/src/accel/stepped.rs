//! Per-cycle stepped reference simulator.
//!
//! A literal state-machine implementation of the dataflow architecture:
//! every module is an Idle/Busy/WaitPush automaton, inter-module FIFOs
//! are explicit [`super::fifo::Fifo`]s, and the main loop advances one
//! clock cycle at a time (with an intra-cycle fixpoint so that a pop and
//! the push it unblocks can land in the same cycle, as combinational
//! FIFO handshakes do).
//!
//! This is deliberately *different machinery* from the max-plus
//! recurrence in [`super::dataflow`]; tests assert the two produce
//! identical cycle counts on every configuration, which validates the
//! fast simulator's semantics.

use super::fifo::Fifo;
use super::dataflow::SimOptions;
use super::reuse::BalancedConfig;

#[derive(Clone, Copy, Debug, PartialEq)]
enum State {
    Idle,
    Busy { done_at: u64, token: usize },
    WaitPush { token: usize },
}

/// Stepped simulation result (subset of the fast simulator's output).
#[derive(Clone, Debug)]
pub struct SteppedResult {
    pub total_cycles: u64,
    /// push time of each output timestep from the last module.
    pub output_times: Vec<u64>,
    /// per-FIFO high-water marks (sizing feedback).
    pub fifo_high_water: Vec<usize>,
}

/// Run the per-cycle reference simulation.
pub fn run_stepped(cfg: &BalancedConfig, opts: SimOptions, t: usize) -> SteppedResult {
    assert!(t >= 1);
    let n = cfg.layers.len();
    let service: Vec<u64> = cfg.layers.iter().map(|l| l.lat_t()).collect();
    let cap = opts.fifo_capacity.max(1);
    // FIFO f[i] feeds module i (for i >= 1). Module 0 reads the DRAM
    // stream directly (the reader's availability schedule is the buffer).
    let mut fifos: Vec<Fifo<usize>> = (1..n).map(|_| Fifo::new(cap)).collect();
    let mut state = vec![State::Idle; n];
    let mut next_token = vec![0usize; n]; // next timestep index each module will pop
    let mut output_times = vec![0u64; t];
    let mut outputs_done = 0usize;

    let reader_avail = |tok: usize| opts.reader_cycles_per_t * (tok as u64 + 1);
    let writer_free = |tok: usize| opts.writer_cycles_per_t * (tok as u64 + 1);

    let mut cycle: u64 = 0;
    // Generous guard: serial execution bound + fills + slack.
    let guard = (t as u64 + n as u64 + 4)
        * (service.iter().sum::<u64>()
            + opts.reader_cycles_per_t
            + opts.writer_cycles_per_t
            + 4)
        + 1_000;
    while outputs_done < t {
        assert!(cycle <= guard, "stepped simulator exceeded cycle guard — deadlock?");
        // Intra-cycle fixpoint: at most N+1 dependent handshakes per cycle.
        for _ in 0..=n {
            let mut changed = false;
            for i in 0..n {
                match state[i] {
                    State::Busy { done_at, token } if done_at <= cycle => {
                        state[i] = State::WaitPush { token };
                        changed = true;
                    }
                    State::WaitPush { token } => {
                        let pushed = if i + 1 < n {
                            fifos[i].try_push(token).is_ok()
                        } else {
                            writer_free(token) <= cycle
                        };
                        if pushed {
                            if i + 1 == n {
                                output_times[token] = cycle;
                                outputs_done += 1;
                            }
                            state[i] = State::Idle;
                            changed = true;
                        }
                    }
                    State::Idle => {
                        let tok = next_token[i];
                        if tok < t {
                            let available = if i == 0 {
                                reader_avail(tok) <= cycle
                            } else {
                                // Peek: pop only if a token is waiting.
                                !fifos[i - 1].is_empty()
                            };
                            if available {
                                if i > 0 {
                                    let got = fifos[i - 1].try_pop().unwrap();
                                    debug_assert_eq!(got, tok, "FIFO order");
                                }
                                next_token[i] += 1;
                                state[i] =
                                    State::Busy { done_at: cycle + service[i], token: tok };
                                changed = true;
                            }
                        }
                    }
                    _ => {}
                }
            }
            if !changed {
                break;
            }
        }
        if outputs_done < t {
            cycle += 1;
        }
    }

    SteppedResult {
        total_cycles: output_times[t - 1],
        output_times,
        fifo_high_water: fifos.iter().map(|f| f.high_water()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dataflow::DataflowSim;
    use crate::model::Topology;
    use crate::util::prop::props;

    #[test]
    fn agrees_with_fast_simulator_on_paper_models() {
        for topo in Topology::paper_models() {
            let rh_m = BalancedConfig::paper_rh_m(&topo.name).unwrap();
            let cfg = BalancedConfig::balance(&topo, rh_m);
            for t in [1usize, 2, 6, 16] {
                let fast = DataflowSim::new(&cfg).run_sequence(t);
                let slow = run_stepped(&cfg, SimOptions::default(), t);
                assert_eq!(fast.total_cycles, slow.total_cycles, "{} T={t}", topo.name);
                assert_eq!(fast.output_times, slow.output_times);
            }
        }
    }

    #[test]
    fn agrees_under_random_configs_fifos_and_rates() {
        props("stepped_vs_fast", 40, |g| {
            let f = 1usize << g.usize_in(3, 5);
            let d = 2 * g.usize_in(1, 3);
            let Ok(topo) = Topology::new(f, d) else { return };
            let cfg = if g.bool() {
                BalancedConfig::balance(&topo, g.u64_below(4) + 1)
            } else {
                BalancedConfig::uniform(&topo, g.u64_below(4) + 1)
            };
            let opts = SimOptions {
                fifo_capacity: g.usize_in(1, 4),
                reader_cycles_per_t: g.u64_below(3) * (f as u64 / 2),
                writer_cycles_per_t: g.u64_below(2) * (f as u64 / 2),
            };
            let t = g.usize_in(1, 24);
            let fast = DataflowSim::with_options(&cfg, opts).run_sequence(t);
            let slow = run_stepped(&cfg, opts, t);
            assert_eq!(
                fast.total_cycles, slow.total_cycles,
                "{} T={t} opts={opts:?}",
                topo.name
            );
            assert_eq!(fast.output_times, slow.output_times);
        });
    }

    #[test]
    fn fifo_high_water_bounded_by_capacity() {
        let topo = Topology::from_name("F32-D6").unwrap();
        let cfg = BalancedConfig::uniform(&topo, 1); // imbalanced → pressure
        let opts = SimOptions { fifo_capacity: 3, ..Default::default() };
        let r = run_stepped(&cfg, opts, 32);
        for hw in r.fifo_high_water {
            assert!(hw <= 3);
        }
    }
}
