//! Automated `RH_m` selection — the paper's stated future work (§3.3:
//! "Determining the optimal RH_m for a given model and platform is future
//! work"). Implemented here as an exact search over the (small, discrete,
//! monotone) design space with three objectives.
//!
//! The space is one-dimensional per model: larger `RH_m` → fewer
//! multipliers → smaller/slower design, with latency strictly increasing
//! and resources non-increasing. That monotonicity (tested) makes exact
//! search over `RH_m ∈ [1, 4·LH_m]` trivial and optimal — no heuristics
//! needed, which is worth knowing relative to the paper's framing.

use super::energy::{energy_per_timestep_mj, fpga_power_w};
use super::latency::LatencyModel;
use super::platform::FpgaDevice;
use super::resources::{estimate, ResourceUsage};
use super::reuse::BalancedConfig;
use crate::model::Topology;

/// What the optimizer should minimize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimum sequence latency at the given T (maximum parallelism that
    /// still fits — the paper's own §4.1 procedure).
    Latency,
    /// Minimum energy per timestep at the given T.
    Energy,
    /// Minimum device area (mean utilization) subject to a latency bound
    /// in milliseconds.
    AreaUnderLatencyBound(u64 /* µs bound */),
}

/// A scored design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub rh_m: u64,
    pub latency_ms: f64,
    pub energy_mj_per_t: f64,
    pub usage: ResourceUsage,
    pub mean_util_pct: f64,
    pub fits: bool,
}

/// Evaluate one design point.
pub fn evaluate(topo: &Topology, dev: &FpgaDevice, rh_m: u64, t: usize) -> DesignPoint {
    let cfg = BalancedConfig::balance(topo, rh_m);
    let lm = LatencyModel::of(&cfg);
    let usage = estimate(&cfg);
    let pct = usage.pct(dev);
    let latency_ms = lm.acc_lat_ms(t, dev.clock_hz);
    let energy = energy_per_timestep_mj(fpga_power_w(&pct, dev), latency_ms, t);
    DesignPoint {
        rh_m,
        latency_ms,
        energy_mj_per_t: energy,
        usage,
        mean_util_pct: pct.mean(),
        fits: usage.fits(dev),
    }
}

/// Exact search for the best fitting `RH_m` under an objective.
/// Returns `None` when nothing fits the device at any reuse factor.
pub fn optimize(
    topo: &Topology,
    dev: &FpgaDevice,
    t: usize,
    objective: Objective,
) -> Option<DesignPoint> {
    let lh_m = topo.layers[topo.widest_layer()].lh as u64;
    let mut best: Option<DesignPoint> = None;
    for rh_m in 1..=(4 * lh_m) {
        let p = evaluate(topo, dev, rh_m, t);
        if !p.fits {
            continue;
        }
        let better = match (&best, objective) {
            (None, _) => true,
            (Some(b), Objective::Latency) => p.latency_ms < b.latency_ms,
            (Some(b), Objective::Energy) => p.energy_mj_per_t < b.energy_mj_per_t,
            (Some(b), Objective::AreaUnderLatencyBound(us)) => {
                let bound = us as f64 / 1e3;
                let p_ok = p.latency_ms <= bound;
                let b_ok = b.latency_ms <= bound;
                match (p_ok, b_ok) {
                    (true, false) => true,
                    (false, _) => false,
                    (true, true) => p.mean_util_pct < b.mean_util_pct,
                }
            }
        };
        if better {
            best = Some(p);
        }
        // Early exit for the latency objective: latency is monotone
        // non-decreasing in RH_m, so the first fitting point is optimal.
        if matches!(objective, Objective::Latency) && best.is_some() {
            break;
        }
    }
    best
}

/// The full (fitting) Pareto front over (latency, mean utilization).
pub fn pareto_front(topo: &Topology, dev: &FpgaDevice, t: usize) -> Vec<DesignPoint> {
    let lh_m = topo.layers[topo.widest_layer()].lh as u64;
    let mut pts: Vec<DesignPoint> =
        (1..=(4 * lh_m)).map(|r| evaluate(topo, dev, r, t)).filter(|p| p.fits).collect();
    pts.sort_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap());
    let mut front: Vec<DesignPoint> = Vec::new();
    let mut best_area = f64::INFINITY;
    for p in pts {
        if p.mean_util_pct < best_area - 1e-12 {
            best_area = p.mean_util_pct;
            front.push(p);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_objective_reproduces_paper_rh_m() {
        // The paper's §4.1 procedure (min RH_m that fits) == our latency
        // objective. Our resource model fits F64-D6 at RH_m 2 where the
        // paper needed 8 (their BRAM realization is heavier, documented);
        // the *procedure* is what we reproduce: the result must fit, and
        // nothing smaller may fit.
        let dev = FpgaDevice::ZCU104;
        for topo in Topology::paper_models() {
            let p = optimize(&topo, &dev, 64, Objective::Latency).expect("fits");
            assert!(p.fits);
            if p.rh_m > 1 {
                assert!(!evaluate(&topo, &dev, p.rh_m - 1, 64).fits, "{}", topo.name);
            }
        }
    }

    #[test]
    fn latency_monotone_in_rh_m() {
        let topo = Topology::from_name("F64-D2").unwrap();
        let dev = FpgaDevice::ZCU104;
        let mut prev = 0.0;
        for rh_m in 1..=32 {
            let p = evaluate(&topo, &dev, rh_m, 64);
            assert!(p.latency_ms >= prev - 1e-12, "rh_m={rh_m}");
            prev = p.latency_ms;
        }
    }

    #[test]
    fn energy_objective_never_worse_than_latency_objective_on_energy() {
        let dev = FpgaDevice::ZCU104;
        for topo in Topology::paper_models() {
            let by_lat = optimize(&topo, &dev, 64, Objective::Latency).unwrap();
            let by_energy = optimize(&topo, &dev, 64, Objective::Energy).unwrap();
            assert!(by_energy.energy_mj_per_t <= by_lat.energy_mj_per_t + 1e-12);
        }
    }

    #[test]
    fn area_objective_respects_bound() {
        let topo = Topology::from_name("F32-D6").unwrap();
        let dev = FpgaDevice::ZCU104;
        // Generous bound: picks something smaller than min-latency design.
        let bound_us = 200u64;
        let p = optimize(&topo, &dev, 64, Objective::AreaUnderLatencyBound(bound_us)).unwrap();
        assert!(p.latency_ms <= bound_us as f64 / 1e3 + 1e-9);
        let min_lat = optimize(&topo, &dev, 64, Objective::Latency).unwrap();
        assert!(p.mean_util_pct <= min_lat.mean_util_pct + 1e-9);
    }

    #[test]
    fn pareto_front_is_monotone() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let front = pareto_front(&topo, &FpgaDevice::ZCU104, 64);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].latency_ms > w[0].latency_ms);
            assert!(w[1].mean_util_pct < w[0].mean_util_pct);
        }
    }

    #[test]
    fn constrained_device_shifts_optimum() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let zcu = optimize(&topo, &FpgaDevice::ZCU104, 64, Objective::Latency).unwrap();
        let u96 = optimize(&topo, &FpgaDevice::ULTRA96, 64, Objective::Latency).unwrap();
        assert!(u96.rh_m > zcu.rh_m);
        assert!(u96.latency_ms > zcu.latency_ms);
    }
}
