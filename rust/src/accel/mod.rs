//! The paper's contribution: a dataflow FPGA accelerator for LSTM
//! autoencoders exploiting **temporal parallelism** — every LSTM layer is
//! its own always-running module, adjacent modules are coupled only by
//! FIFOs, and in steady state module *i* processes timestep *t − i* while
//! its neighbours work on adjacent timesteps (§3).
//!
//! Submodules:
//! - [`reuse`] — hardware reuse factors and the **dataflow balancing
//!   methodology** (paper Eqs 5–8).
//! - [`latency`] — the analytical per-timestep / whole-sequence latency
//!   model (Eqs 1–4).
//! - [`fifo`] — cycle-stamped bounded FIFO used by the simulators.
//! - [`mvm`] — MVM_X / MVM_H unit model (timing + functional compute).
//! - [`lstm_module`] — one `LSTM_i` dataflow module.
//! - [`dataflow`] — the fast cycle-accurate simulator (max-plus recurrence
//!   over (module, timestep), exact for constant service times with
//!   blocking-after-service semantics) plus functional execution.
//! - [`stepped`] — a per-cycle, element-granular reference simulator used
//!   to validate [`dataflow`] on small configs.
//! - [`layer_by_layer`] — the prior-work baseline (one layer at a time,
//!   §3.4's "traditional layer-by-layer execution") for the ablation.
//! - [`resources`] — XCZU7EV resource model → Table 1.
//! - [`energy`] — platform power/energy models → Table 3.
//! - [`platform`] — FPGA device catalog.

pub mod reuse;
pub mod latency;
pub mod fifo;
pub mod mvm;
pub mod lstm_module;
pub mod dataflow;
pub mod stepped;
pub mod layer_by_layer;
pub mod resources;
pub mod energy;
pub mod platform;
pub mod optimizer;
pub mod multi;

pub use dataflow::DataflowSim;
pub use latency::LatencyModel;
pub use reuse::BalancedConfig;
