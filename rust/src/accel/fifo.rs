//! Bounded FIFO with cycle-stamped occupancy tracking — the only
//! inter-module communication mechanism in the dataflow architecture
//! (§3.1: "inter-module communication exclusively through FIFO queues").
//!
//! The payload is a timestep-vector token; the simulators care about
//! *when* tokens move, the functional path about *what* they carry.

use std::collections::VecDeque;

/// A bounded FIFO of tokens `T` with high-water-mark tracking.
#[derive(Clone, Debug)]
pub struct Fifo<T> {
    items: VecDeque<T>,
    capacity: usize,
    /// Maximum occupancy ever observed (sizing feedback for HLS).
    high_water: usize,
    /// Counts of rejected pushes (upstream stall events).
    push_stalls: u64,
    /// Counts of failed pops (downstream starvation events).
    pop_starves: u64,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Fifo<T> {
        assert!(capacity >= 1, "FIFO capacity must be >= 1");
        Fifo {
            items: VecDeque::with_capacity(capacity),
            capacity,
            high_water: 0,
            push_stalls: 0,
            pop_starves: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Try to push; on a full FIFO records a stall and returns the token
    /// back (the producer must hold it and retry — blocking-after-service).
    pub fn try_push(&mut self, token: T) -> Result<(), T> {
        if self.is_full() {
            self.push_stalls += 1;
            return Err(token);
        }
        self.items.push_back(token);
        self.high_water = self.high_water.max(self.items.len());
        Ok(())
    }

    /// Try to pop; on an empty FIFO records a starvation event.
    pub fn try_pop(&mut self) -> Option<T> {
        match self.items.pop_front() {
            Some(t) => Some(t),
            None => {
                self.pop_starves += 1;
                None
            }
        }
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn push_stalls(&self) -> u64 {
        self.push_stalls
    }

    pub fn pop_starves(&self) -> u64 {
        self.pop_starves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn fifo_ordering() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.try_push(i).unwrap();
        }
        assert!(f.is_full());
        assert_eq!(f.try_push(99), Err(99));
        assert_eq!(f.push_stalls(), 1);
        for i in 0..4 {
            assert_eq!(f.try_pop(), Some(i));
        }
        assert_eq!(f.try_pop(), None);
        assert_eq!(f.pop_starves(), 1);
    }

    #[test]
    fn high_water_tracks_max() {
        let mut f = Fifo::new(8);
        f.try_push(1).unwrap();
        f.try_push(2).unwrap();
        f.try_pop();
        f.try_push(3).unwrap();
        assert_eq!(f.high_water(), 2);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u8>::new(0);
    }

    #[test]
    fn never_exceeds_capacity_under_random_ops() {
        props("fifo_cap", 128, |g| {
            let cap = g.usize_in(1, 8);
            let mut f = Fifo::new(cap);
            let mut pushed = 0u64;
            let mut popped = 0u64;
            for _ in 0..200 {
                if g.bool() {
                    if f.try_push(pushed).is_ok() {
                        pushed += 1;
                    }
                } else if let Some(v) = f.try_pop() {
                    assert_eq!(v, popped, "FIFO order");
                    popped += 1;
                }
                assert!(f.len() <= cap);
            }
            assert_eq!(f.len() as u64, pushed - popped);
        });
    }
}
