//! Prior-work baseline: layer-by-layer execution (§3.4's "traditional
//! layer-by-layer execution, which underutilizes hardware").
//!
//! The same per-layer hardware processes the whole sequence through layer
//! 0, writes the intermediate hidden sequence to DRAM, reloads it, runs
//! layer 1, and so on — the execution style of single-layer LSTM
//! accelerators [2, 3, 7] and (across one layer's timesteps) SHARP [1].
//! No temporal overlap across layers exists, and intermediate sequences
//! round-trip through global memory.
//!
//! Used by ablation A2 (`cargo bench --bench ablation_temporal`).

use super::reuse::BalancedConfig;

/// DRAM round-trip model for intermediate sequences.
#[derive(Clone, Copy, Debug)]
pub struct MemModel {
    /// Words (32-bit) transferred per cycle on the DDR interface
    /// (ZCU104: 64-bit DDR4 @ ~1200 MT/s against a 300 MHz kernel ≈ 8
    /// words/cycle peak; 4 is a realistic sustained figure).
    pub words_per_cycle: u64,
    /// Fixed DMA descriptor/setup cycles per transfer direction.
    pub setup_cycles: u64,
}

impl Default for MemModel {
    fn default() -> Self {
        MemModel { words_per_cycle: 4, setup_cycles: 200 }
    }
}

/// Result of the layer-by-layer execution model.
#[derive(Clone, Debug)]
pub struct LayerByLayerResult {
    pub total_cycles: u64,
    pub compute_cycles: u64,
    pub dram_cycles: u64,
}

/// Simulate layer-by-layer execution of a `t`-timestep sequence.
///
/// Compute per layer is `T · Lat_t_i` (the same per-timestep service as
/// the dataflow modules — recurrent dependence serializes timesteps
/// within a layer). Between layers the hidden sequence `T·LH_i` words is
/// written to and read back from DRAM.
pub fn run_layer_by_layer(
    cfg: &BalancedConfig,
    mem: MemModel,
    t: usize,
) -> LayerByLayerResult {
    assert!(t >= 1);
    let mut compute = 0u64;
    let mut dram = 0u64;
    let n = cfg.layers.len();
    for (i, l) in cfg.layers.iter().enumerate() {
        compute += t as u64 * l.lat_t();
        if i + 1 < n {
            // Write h sequence out, read it back for the next layer.
            let words = t as u64 * l.lh as u64;
            let per_dir = super::reuse::div_ceil(words, mem.words_per_cycle) + mem.setup_cycles;
            dram += 2 * per_dir;
        }
    }
    LayerByLayerResult { total_cycles: compute + dram, compute_cycles: compute, dram_cycles: dram }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dataflow::DataflowSim;
    use crate::accel::latency::LatencyModel;
    use crate::model::Topology;
    use crate::util::prop::props;

    #[test]
    fn compute_matches_serial_model() {
        let topo = Topology::from_name("F32-D6").unwrap();
        let cfg = BalancedConfig::balance(&topo, 1);
        let lm = LatencyModel::of(&cfg);
        let r = run_layer_by_layer(&cfg, MemModel { words_per_cycle: 4, setup_cycles: 0 }, 16);
        assert_eq!(r.compute_cycles, lm.serial_lat(16));
    }

    #[test]
    fn dataflow_always_wins_and_gap_grows_with_depth() {
        props("temporal_wins", 48, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            let cfg = BalancedConfig::paper_config(&topo);
            let t = g.usize_in(2, 64);
            let lbl = run_layer_by_layer(&cfg, MemModel::default(), t);
            let df = DataflowSim::new(&cfg).run_sequence(t);
            assert!(
                lbl.total_cycles > df.total_cycles,
                "{} T={t}: lbl {} df {}",
                topo.name,
                lbl.total_cycles,
                df.total_cycles
            );
        });
        // Speedup at T=64 is larger for D6 than D2 (temporal parallelism
        // scales with depth).
        let s = |name: &str| {
            let topo = Topology::from_name(name).unwrap();
            let cfg = BalancedConfig::paper_config(&topo);
            let lbl = run_layer_by_layer(&cfg, MemModel::default(), 64).total_cycles as f64;
            let df = DataflowSim::new(&cfg).run_sequence(64).total_cycles as f64;
            lbl / df
        };
        assert!(s("F32-D6") > s("F32-D2") * 1.5, "D6 {} D2 {}", s("F32-D6"), s("F32-D2"));
    }

    #[test]
    fn dram_traffic_counted_only_between_layers() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let cfg = BalancedConfig::balance(&topo, 1);
        let mem = MemModel { words_per_cycle: 4, setup_cycles: 100 };
        let r = run_layer_by_layer(&cfg, mem, 8);
        // One boundary (L0→L1): 8·16 words = 128 → 32 cycles + setup, ×2.
        assert_eq!(r.dram_cycles, 2 * (128 / 4 + 100));
    }
}
