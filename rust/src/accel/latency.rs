//! Analytical latency model (paper §3.2, Eqs 1–4).
//!
//! ```text
//! Acc_Lat = T · Lat_t_m  +  Σ_{i<m} Lat_t_i  +  Σ_{i>m} Lat_t_i      (1)
//! Lat_t_i = max(X_t_i, H_t_i)                                        (2)
//! X_t_i   = LX_i·RX_i + LH_i                                         (3)
//! H_t_i   = LH_i·RH_i + LH_i                                         (4)
//! ```
//!
//! Eq 1 decomposes into the steady-state term (T repetitions of the
//! bottleneck stage) plus the pipeline fill/drain contribution of every
//! other stage. The cycle-accurate simulator ([`super::dataflow`]) must
//! reproduce this exactly for balanced configs with adequate FIFOs —
//! an integration test asserts it.

use super::reuse::BalancedConfig;

/// Analytical latency results for one configuration.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// Per-module per-timestep latencies `Lat_t_i` (cycles).
    pub lat_t: Vec<u64>,
    /// Bottleneck module index m.
    pub m: usize,
}

impl LatencyModel {
    pub fn of(cfg: &BalancedConfig) -> LatencyModel {
        let lat_t: Vec<u64> = cfg.layers.iter().map(|l| l.lat_t()).collect();
        let mut m = 0;
        for (i, &l) in lat_t.iter().enumerate() {
            if l > lat_t[m] {
                m = i;
            }
        }
        LatencyModel { lat_t, m }
    }

    /// The bottleneck per-timestep latency `Lat_t_m` (cycles).
    pub fn lat_t_m(&self) -> u64 {
        self.lat_t[self.m]
    }

    /// Eq 1: total cycles to process a sequence of `t` timesteps.
    pub fn acc_lat(&self, t: usize) -> u64 {
        assert!(t >= 1, "sequence length must be >= 1");
        let fill: u64 = self
            .lat_t
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != self.m)
            .map(|(_, &l)| l)
            .sum();
        t as u64 * self.lat_t_m() + fill
    }

    /// Latency in milliseconds at clock `hz`.
    pub fn acc_lat_ms(&self, t: usize, hz: f64) -> f64 {
        crate::cycles_to_ms(self.acc_lat(t), hz)
    }

    /// Throughput in timesteps/second once the pipeline is full.
    pub fn steady_state_rate(&self, hz: f64) -> f64 {
        hz / self.lat_t_m() as f64
    }

    /// The layer-by-layer (no temporal parallelism) latency of the same
    /// hardware: each timestep of each layer executes serially —
    /// `T · Σ_i Lat_t_i`. Prior-work style baseline used by ablation A2
    /// (see also [`super::layer_by_layer`] for the simulated version).
    pub fn serial_lat(&self, t: usize) -> u64 {
        t as u64 * self.lat_t.iter().sum::<u64>()
    }

    /// Speedup of the dataflow execution over layer-by-layer on the same
    /// hardware (the value temporal parallelism buys).
    pub fn temporal_speedup(&self, t: usize) -> f64 {
        self.serial_lat(t) as f64 / self.acc_lat(t) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::prop::props;

    #[test]
    fn f32d2_hand_computed() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let cfg = BalancedConfig::balance(&topo, 1);
        let lm = LatencyModel::of(&cfg);
        // Both layers have Lat_t = 64 (see reuse.rs tests). m is layer 0
        // or 1 (tie); fill = 64, steady = 64·T.
        assert_eq!(lm.lat_t, vec![64, 64]);
        assert_eq!(lm.acc_lat(1), 64 + 64);
        assert_eq!(lm.acc_lat(64), 64 * 64 + 64);
        // At 300 MHz: 64 timesteps → (4096+64)/300e6 s = 0.01387 ms.
        let ms = lm.acc_lat_ms(64, 300.0e6);
        assert!((ms - 4160.0 / 300.0e6 * 1e3).abs() < 1e-12);
    }

    #[test]
    fn latency_is_affine_in_t() {
        props("affine_in_t", 64, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            let rh_m = g.u64_below(6) + 1;
            let lm = LatencyModel::of(&BalancedConfig::balance(&topo, rh_m));
            let t1 = g.usize_in(1, 100);
            let t2 = t1 + g.usize_in(1, 100);
            let slope = (lm.acc_lat(t2) - lm.acc_lat(t1)) / (t2 - t1) as u64;
            assert_eq!(slope, lm.lat_t_m());
        });
    }

    #[test]
    fn deeper_models_add_fill_not_slope() {
        // The paper's depth-scalability claim in analytical form: D6 and
        // D2 at the same width share the bottleneck layer (widest = F),
        // so the *slope* over T is identical; depth only adds fill.
        for f in [32usize, 64] {
            let d2 = LatencyModel::of(&BalancedConfig::balance(
                &Topology::new(f, 2).unwrap(),
                1,
            ));
            let d6 = LatencyModel::of(&BalancedConfig::balance(
                &Topology::new(f, 6).unwrap(),
                1,
            ));
            assert_eq!(d2.lat_t_m(), d6.lat_t_m(), "F{f}");
            assert!(d6.acc_lat(64) > d2.acc_lat(64));
            let added = d6.acc_lat(64) - d2.acc_lat(64);
            // Added fill is bounded by the extra stages' latencies.
            let extra: u64 = d6.lat_t.iter().sum::<u64>() - d2.lat_t.iter().sum::<u64>();
            assert!(added <= extra, "added {added} extra {extra}");
        }
    }

    #[test]
    fn temporal_speedup_approaches_depth_for_balanced_long_seq() {
        // Perfectly balanced N-stage pipeline: serial = T·N·L,
        // dataflow = T·L + (N−1)·L ⇒ speedup → N as T → ∞.
        let topo = Topology::from_name("F32-D6").unwrap();
        let lm = LatencyModel::of(&BalancedConfig::balance(&topo, 1));
        let s = lm.temporal_speedup(1024);
        assert!(s > 5.5 && s <= 6.0, "speedup {s}");
    }

    #[test]
    fn steady_state_rate_matches_bottleneck() {
        let topo = Topology::from_name("F64-D2").unwrap();
        let lm = LatencyModel::of(&BalancedConfig::balance(&topo, 4));
        let rate = lm.steady_state_rate(300.0e6);
        assert!((rate - 300.0e6 / lm.lat_t_m() as f64).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "sequence length")]
    fn rejects_t_zero() {
        let topo = Topology::from_name("F32-D2").unwrap();
        LatencyModel::of(&BalancedConfig::balance(&topo, 1)).acc_lat(0);
    }
}
