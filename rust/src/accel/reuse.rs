//! Hardware reuse factors and the dataflow-balancing methodology
//! (paper §3.2–3.3, Eqs 5–8).
//!
//! A reuse factor is the number of cycles an MVM unit spends per input
//! element; it is inversely proportional to the number of parallel
//! multipliers (Eqs 5–6):
//!
//! ```text
//! RX_i = 4·LH_i / MX_i        RH_i = 4·LH_i / MH_i
//! ```
//!
//! Balancing happens at two levels:
//! 1. **intra-module** (Eq 7): `RX_i = (LH_i / LX_i) · RH_i` so MVM_X and
//!    MVM_H finish a timestep together;
//! 2. **inter-module** (Eq 8): `RH_i = (LH_m − LH_i)/LH_i + (LH_m/LH_i)·RH_m`
//!    so every module's per-timestep latency equals the bottleneck
//!    module's.
//!
//! With the exact (real-valued) factors both balances are *identities*:
//! substituting Eq 7 into Eq 3 gives `X_t_i = H_t_i`, and Eq 8 into Eq 4
//! gives `H_t_i = H_t_m` (tests verify both).
//!
//! **Integer quantization.** What hardware actually quantizes is the
//! *multiplier count*, not the reuse factor: an MVM unit instantiates
//! `M = ⌈4·LH/R_exact⌉` multipliers and streams `4·LH·n_in` MACs through
//! them, taking `⌈n_in·4·LH / M⌉` compute cycles (a fractional reuse
//! factor like 1.5 is simply an element schedule alternating 1- and
//! 2-cycle elements). Rounding the multiplier count *up* keeps every
//! module at least as fast as the exact balance, so the designated
//! bottleneck still dominates and Eq 1 stays exact.

use crate::model::topology::Topology;

/// Hardware configuration of one `LSTM_i` module.
#[derive(Clone, Debug)]
pub struct LayerHw {
    /// Input feature dimension `LX_i`.
    pub lx: usize,
    /// Hidden dimension `LH_i`.
    pub lh: usize,
    /// Exact balanced reuse factors (Eqs 7–8).
    pub rx_exact: f64,
    pub rh_exact: f64,
    /// Parallel multiplier counts (Eqs 5–6 inverted, ceil).
    pub mx: u64,
    pub mh: u64,
}

impl LayerHw {
    /// Per-timestep latency of MVM_X (Eq 3 with the integer multiplier
    /// schedule): `⌈LX·4·LH / MX⌉ + LH`.
    pub fn x_t(&self) -> u64 {
        div_ceil(self.lx as u64 * 4 * self.lh as u64, self.mx) + self.lh as u64
    }

    /// Per-timestep latency of MVM_H (Eq 4): `⌈LH·4·LH / MH⌉ + LH`.
    pub fn h_t(&self) -> u64 {
        div_ceil(self.lh as u64 * 4 * self.lh as u64, self.mh) + self.lh as u64
    }

    /// Module per-timestep latency (Eq 2): `max(X_t, H_t)`.
    pub fn lat_t(&self) -> u64 {
        self.x_t().max(self.h_t())
    }

    /// Effective integer-schedule reuse factors (cycles per element,
    /// averaged): `4·LH / M`.
    pub fn rx_effective(&self) -> f64 {
        4.0 * self.lh as f64 / self.mx as f64
    }

    pub fn rh_effective(&self) -> f64 {
        4.0 * self.lh as f64 / self.mh as f64
    }

    /// Total multipliers in the module.
    pub fn multipliers(&self) -> u64 {
        self.mx + self.mh
    }
}

/// A fully-configured accelerator: one [`LayerHw`] per LSTM layer.
#[derive(Clone, Debug)]
pub struct BalancedConfig {
    pub topo: Topology,
    pub layers: Vec<LayerHw>,
    /// The primary reuse factor `RH_m` the design was balanced around.
    pub rh_m: u64,
    /// Index of the bottleneck module m.
    pub bottleneck: usize,
}

impl BalancedConfig {
    /// The paper's balancing methodology (§3.3): given the topology and the
    /// primary reuse factor `RH_m` of the bottleneck (widest) layer, derive
    /// every layer's reuse factors via Eqs 7–8, then size multiplier arrays.
    pub fn balance(topo: &Topology, rh_m: u64) -> BalancedConfig {
        assert!(rh_m >= 1, "RH_m must be >= 1");
        let m = topo.widest_layer();
        let lh_m = topo.layers[m].lh as f64;
        let layers = topo
            .layers
            .iter()
            .map(|d| {
                let lh_i = d.lh as f64;
                let lx_i = d.lx as f64;
                // Eq 8: equalize module latency with the bottleneck.
                let rh_exact = (lh_m - lh_i) / lh_i + (lh_m / lh_i) * rh_m as f64;
                // Eq 7: equalize MVM_X with MVM_H inside the module.
                let rx_exact = (lh_i / lx_i) * rh_exact;
                // Eqs 5–6 inverted: enough multipliers to sustain the
                // exact factors (ceil ⇒ at least as fast as balance).
                let mx = (4.0 * lh_i / rx_exact).ceil() as u64;
                let mh = (4.0 * lh_i / rh_exact).ceil() as u64;
                LayerHw { lx: d.lx, lh: d.lh, rx_exact, rh_exact, mx: mx.max(1), mh: mh.max(1) }
            })
            .collect();
        BalancedConfig { topo: topo.clone(), layers, rh_m, bottleneck: m }
    }

    /// Deliberately *unbalanced* configuration for the ablation (A1):
    /// every layer gets the same reuse factor `RX = RH = r` — the naive
    /// "give every layer identical per-element parallelism" choice the
    /// paper argues against.
    pub fn uniform(topo: &Topology, r: u64) -> BalancedConfig {
        assert!(r >= 1);
        let layers = topo
            .layers
            .iter()
            .map(|d| LayerHw {
                lx: d.lx,
                lh: d.lh,
                rx_exact: r as f64,
                rh_exact: r as f64,
                mx: div_ceil(4 * d.lh as u64, r),
                mh: div_ceil(4 * d.lh as u64, r),
            })
            .collect();
        let mut cfg = BalancedConfig { topo: topo.clone(), layers, rh_m: r, bottleneck: 0 };
        cfg.bottleneck = cfg.bottleneck_by_latency();
        cfg
    }

    /// The module with the largest per-timestep latency (`Lat_t_m`).
    pub fn bottleneck_by_latency(&self) -> usize {
        let mut m = 0;
        for (i, l) in self.layers.iter().enumerate() {
            if l.lat_t() > self.layers[m].lat_t() {
                m = i;
            }
        }
        m
    }

    /// The paper's `RH_m` values for the four evaluated models (Table 1).
    pub fn paper_rh_m(model_name: &str) -> Option<u64> {
        match model_name.trim_start_matches("LSTM-AE-") {
            "F32-D2" => Some(1),
            "F64-D2" => Some(4),
            "F32-D6" => Some(1),
            "F64-D6" => Some(8),
            _ => None,
        }
    }

    /// Build the paper's Table-1 configuration for a paper model.
    pub fn paper_config(topo: &Topology) -> BalancedConfig {
        let rh_m = Self::paper_rh_m(&topo.name).unwrap_or(1);
        Self::balance(topo, rh_m)
    }

    /// Total multipliers across all modules.
    pub fn total_multipliers(&self) -> u64 {
        self.layers.iter().map(|l| l.multipliers()).sum()
    }

    /// Worst-case imbalance: min/max of per-module latency — 1.0 means
    /// perfectly balanced (every module equally busy).
    pub fn balance_ratio(&self) -> f64 {
        let max = self.layers.iter().map(|l| l.lat_t()).max().unwrap() as f64;
        let min = self.layers.iter().map(|l| l.lat_t()).min().unwrap() as f64;
        min / max
    }
}

pub(crate) fn div_ceil(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn eq7_makes_mvms_equal_exactly() {
        // With real-valued factors, X_t == H_t identically.
        for topo in Topology::paper_models() {
            for rh_m in [1u64, 2, 4, 8] {
                let cfg = BalancedConfig::balance(&topo, rh_m);
                for l in &cfg.layers {
                    let x = l.lx as f64 * l.rx_exact + l.lh as f64;
                    let h = l.lh as f64 * l.rh_exact + l.lh as f64;
                    assert!((x - h).abs() < 1e-9, "X_t={x} H_t={h}");
                }
            }
        }
    }

    #[test]
    fn eq8_equalizes_module_latency_exactly() {
        for topo in Topology::paper_models() {
            let cfg = BalancedConfig::balance(&topo, 4);
            let m = cfg.bottleneck;
            let lm = &cfg.layers[m];
            let h_m = lm.lh as f64 * lm.rh_exact + lm.lh as f64;
            for l in &cfg.layers {
                let h_i = l.lh as f64 * l.rh_exact + l.lh as f64;
                assert!((h_i - h_m).abs() < 1e-9, "H_t_i={h_i} H_t_m={h_m}");
            }
        }
    }

    #[test]
    fn bottleneck_dominates_after_integer_sizing() {
        // Ceil on multiplier counts only makes non-bottleneck modules
        // faster; the designated bottleneck keeps Lat_t = LH_m·RH_m + LH_m.
        props("bottleneck_dominates", 96, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            let rh_m = g.u64_below(8) + 1;
            let cfg = BalancedConfig::balance(&topo, rh_m);
            let m = cfg.bottleneck;
            let eq4 = cfg.layers[m].lh as u64 * rh_m + cfg.layers[m].lh as u64;
            let got = cfg.layers[m].lat_t();
            // Ceil on the multiplier count can only make the bottleneck
            // (slightly) faster than Eq 4, never slower.
            assert!(got <= eq4, "{}: {got} > Eq4 {eq4}", topo.name);
            assert!(got as f64 >= 0.95 * eq4 as f64, "{}: {got} << Eq4 {eq4}", topo.name);
            for l in &cfg.layers {
                assert!(l.lat_t() <= eq4, "{}: {} > bottleneck {eq4}", topo.name, l.lat_t());
            }
        });
    }

    #[test]
    fn balanced_configs_are_tightly_balanced() {
        // D2 models balance almost perfectly; D6 models contain tiny
        // middle layers (LH = 4–8) that cannot be slowed below a single
        // multiplier, so they run *faster* than the bottleneck (never
        // slower — which is what matters for Eq 1) and the min/max ratio
        // drops. Balanced must always beat the uniform strawman.
        for topo in Topology::paper_models() {
            let rh_m = BalancedConfig::paper_rh_m(&topo.name).unwrap();
            let bal = BalancedConfig::balance(&topo, rh_m);
            let uni = BalancedConfig::uniform(&topo, rh_m);
            assert!(
                bal.balance_ratio() >= uni.balance_ratio(),
                "{}: balanced {} vs uniform {}",
                topo.name,
                bal.balance_ratio(),
                uni.balance_ratio()
            );
            if topo.depth == 2 {
                assert!(bal.balance_ratio() > 0.95, "{}: {}", topo.name, bal.balance_ratio());
            }
        }
    }

    #[test]
    fn paper_f32d2_reuse_values() {
        // Hand-computed from Eqs 7–8: layers 32→16 and 16→32, RH_m = 1.
        let topo = Topology::from_name("F32-D2").unwrap();
        let cfg = BalancedConfig::balance(&topo, 1);
        // Layer 1 (bottleneck, 16→32): RH = 1, RX = 32/16·1 = 2.
        assert_eq!(cfg.layers[1].rh_exact, 1.0);
        assert_eq!(cfg.layers[1].rx_exact, 2.0);
        assert_eq!(cfg.layers[1].mx, 64);
        assert_eq!(cfg.layers[1].mh, 128);
        // Layer 0 (32→16): RH = (32−16)/16 + 32/16 = 3, RX = 1.5 ⇒ MX = ⌈64/1.5⌉ = 43.
        assert_eq!(cfg.layers[0].rh_exact, 3.0);
        assert_eq!(cfg.layers[0].rx_exact, 1.5);
        assert_eq!(cfg.layers[0].mx, 43);
        assert_eq!(cfg.layers[0].mh, 22);
        // Latencies: both modules land on the bottleneck's 64 cycles.
        assert_eq!(cfg.layers[1].lat_t(), 64);
        assert_eq!(cfg.layers[0].lat_t(), 64);
    }

    #[test]
    fn uniform_config_is_imbalanced_for_ae_topologies() {
        let topo = Topology::from_name("F32-D6").unwrap();
        let bal = BalancedConfig::balance(&topo, 1);
        let uni = BalancedConfig::uniform(&topo, 1);
        assert!(bal.balance_ratio() > 0.7, "balanced ratio {}", bal.balance_ratio());
        assert!(uni.balance_ratio() < 0.5, "uniform ratio {}", uni.balance_ratio());
    }

    #[test]
    fn multipliers_scale_inversely_with_rh_m() {
        let topo = Topology::from_name("F64-D2").unwrap();
        let m1 = BalancedConfig::balance(&topo, 1).total_multipliers();
        let m4 = BalancedConfig::balance(&topo, 4).total_multipliers();
        let m8 = BalancedConfig::balance(&topo, 8).total_multipliers();
        assert!(m1 > 2 * m4, "m1={m1} m4={m4}");
        assert!(m4 > m8, "m4={m4} m8={m8}");
    }

    #[test]
    fn mx_mh_meet_throughput() {
        // M multipliers at the exact reuse factor cover all 4·LH MACs per
        // element: M ≥ 4·LH/R_exact.
        props("throughput", 128, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            let rh_m = g.u64_below(8) + 1;
            for l in BalancedConfig::balance(&topo, rh_m).layers {
                assert!(l.mx as f64 * l.rx_exact >= 4.0 * l.lh as f64 - 1e-9);
                assert!(l.mh as f64 * l.rh_exact >= 4.0 * l.lh as f64 - 1e-9);
            }
        });
    }

    #[test]
    fn effective_reuse_close_to_exact() {
        props("eff_reuse", 64, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            let cfg = BalancedConfig::balance(&topo, g.u64_below(6) + 1);
            for l in &cfg.layers {
                assert!(l.rx_effective() <= l.rx_exact + 1e-9);
                assert!(l.rh_effective() <= l.rh_exact + 1e-9);
                // Never more than 2x faster than asked (ceil of small counts).
                assert!(l.rx_effective() * 2.0 + 1e-9 >= l.rx_exact.min(4.0 * l.lh as f64));
            }
        });
    }
}
