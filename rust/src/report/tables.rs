//! Generators for the paper's tables and figures. Each returns a rendered
//! ASCII table (and, where useful, a machine-readable JSON blob) so the
//! CLI, the benches, and EXPERIMENTS.md all share one source of truth.

use crate::accel::energy::{energy_per_timestep_mj, fpga_power_w};
use crate::accel::platform::FpgaDevice;
use crate::accel::resources::estimate;
use crate::accel::reuse::BalancedConfig;
use crate::accel::DataflowSim;
use crate::baselines::{CalibratedModel, Platform};
use crate::model::Topology;
use crate::util::table::{ms, pct, speedup, Table};

use super::paper_data;

/// Fixed PS→PL invocation overhead (ms) of a Zynq MPSoC kernel launch:
/// DMA descriptor setup + interrupt + driver return. Calibrated from the
/// paper's own T=1 rows (measured 33–60 µs against a 0.4–2 µs kernel —
/// the constant gap is the platform, not the datapath). A single global
/// constant; see DESIGN.md §6.
pub const PS_INVOCATION_OVERHEAD_MS: f64 = 0.020;

/// Kernel-only latency of one model/T on our simulated accelerator
/// (ms @ 300 MHz) — the paper's Eq-1 quantity.
pub fn fpga_latency_ms(topo: &Topology, t: usize) -> f64 {
    let cfg = BalancedConfig::paper_config(topo);
    DataflowSim::new(&cfg).run_sequence(t).total_ms(FpgaDevice::ZCU104.clock_hz)
}

/// End-to-end latency estimate: kernel + PS invocation overhead — the
/// quantity comparable to the paper's Table-2 FPGA column.
pub fn fpga_platform_latency_ms(topo: &Topology, t: usize) -> f64 {
    PS_INVOCATION_OVERHEAD_MS + fpga_latency_ms(topo, t)
}

/// Table 1: FPGA resource utilization (%) and RH_m — model vs paper.
pub fn table1() -> String {
    let dev = FpgaDevice::ZCU104;
    let mut t =
        Table::new("Table 1 — FPGA resource utilization (%) and reuse factor RH_m (model vs paper)")
            .header(&["Name", "RH_m", "LUT%", "FF%", "BRAM%", "DSP%", "fits"]);
    for (name, rh_m, lut_p, ff_p, bram_p, dsp_p) in paper_data::TABLE1 {
        let topo = Topology::from_name(name).unwrap();
        let cfg = BalancedConfig::balance(&topo, rh_m);
        let u = estimate(&cfg).pct(&dev);
        t.row(vec![
            name.to_string(),
            format!("{rh_m}"),
            pct(u.lut),
            pct(u.ff),
            pct(u.bram),
            pct(u.dsp),
            if estimate(&cfg).fits(&dev) { "yes".into() } else { "NO".into() },
        ]);
        t.row(vec![
            "  (paper)".to_string(),
            format!("{rh_m}"),
            pct(lut_p),
            pct(ff_p),
            pct(bram_p),
            pct(dsp_p),
            "yes".into(),
        ]);
        t.separator();
    }
    t.render()
}

/// Options controlling which latency sources Table 2 includes.
#[derive(Clone, Copy, Debug, Default)]
pub struct Table2Options {
    /// Include a measured XLA-CPU column via the runtime (needs artifacts).
    pub measured_cpu: Option<MeasuredCpu>,
}

/// Callback type: measured CPU latency in ms for (model, t).
pub type MeasuredCpu = fn(&str, usize) -> Option<f64>;

/// Table 2: inference latency (ms) — FPGA(sim) vs calibrated CPU/GPU,
/// with the paper's numbers inline.
pub fn table2(measured_cpu: Option<&dyn Fn(&str, usize) -> Option<f64>>) -> String {
    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let mut out = String::new();
    for col in &paper_data::TABLE2 {
        let topo = Topology::from_name(col.model).unwrap();
        let mut t = Table::new(&format!("Table 2 — Inference latency (ms), {}", col.model))
            .header(&[
                "T",
                "FPGA(kernel)",
                "FPGA(+ovh)",
                "CPU(model)",
                "GPU(model)",
                "CPU(measured XLA)",
                "FPGA(paper)",
                "CPU(paper)",
                "GPU(paper)",
            ]);
        for (i, &steps) in paper_data::TIMESTEPS.iter().enumerate() {
            let kernel = fpga_latency_ms(&topo, steps);
            let fpga = fpga_platform_latency_ms(&topo, steps);
            let c = cpu.latency_ms(&topo, steps);
            let g = gpu.latency_ms(&topo, steps);
            let measured = measured_cpu
                .and_then(|f| f(col.model, steps))
                .map(|v| format!("{} {}", ms(v), speedup(v / fpga)))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                steps.to_string(),
                ms(kernel),
                ms(fpga),
                format!("{} {}", ms(c), speedup(c / fpga)),
                format!("{} {}", ms(g), speedup(g / fpga)),
                measured,
                ms(col.fpga[i]),
                format!("{} {}", ms(col.cpu[i]), speedup(col.cpu[i] / col.fpga[i])),
                format!("{} {}", ms(col.gpu[i]), speedup(col.gpu[i] / col.fpga[i])),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Table 3: energy per timestep (mJ).
pub fn table3() -> String {
    let dev = FpgaDevice::ZCU104;
    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let mut out = String::new();
    for col in &paper_data::TABLE2 {
        let topo = Topology::from_name(col.model).unwrap();
        let cfg = BalancedConfig::paper_config(&topo);
        let p_fpga = fpga_power_w(&estimate(&cfg).pct(&dev), &dev);
        let mut t = Table::new(&format!(
            "Table 3 — Energy per timestep (mJ), {} (P_fpga model {:.1} W)",
            col.model, p_fpga
        ))
        .header(&[
            "T",
            "FPGA(sim+ovh)",
            "CPU(model)",
            "GPU(model)",
            "FPGA(paper*)",
            "CPU(paper*)",
            "GPU(paper*)",
        ]);
        for (i, &steps) in paper_data::TIMESTEPS.iter().enumerate() {
            // Platform-adjusted latency: consistent with the paper's
            // wall-clock energy accounting.
            let fpga_lat = fpga_platform_latency_ms(&topo, steps);
            let e_f = energy_per_timestep_mj(p_fpga, fpga_lat, steps);
            let e_c = cpu.energy_per_timestep_mj(&topo, steps);
            let e_g = gpu.energy_per_timestep_mj(&topo, steps);
            let p_f = paper_data::table3_derived(col.model, i, "fpga").unwrap();
            let p_c = paper_data::table3_derived(col.model, i, "cpu").unwrap();
            let p_g = paper_data::table3_derived(col.model, i, "gpu").unwrap();
            t.row(vec![
                steps.to_string(),
                format!("{e_f:.3}"),
                format!("{e_c:.3} {}", speedup(e_c / e_f)),
                format!("{e_g:.3} {}", speedup(e_g / e_f)),
                format!("{p_f:.3}"),
                format!("{p_c:.3} {}", speedup(p_c / p_f)),
                format!("{p_g:.3} {}", speedup(p_g / p_f)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str("(*) paper columns derived from Table-2 latencies via the paper's E = P·lat/T with its reported power bands; legible Table-3 cells validate this within a few percent.\n");
    out
}

/// Depth-scalability figure (§4.2): latency at T=64 vs depth for F64.
pub fn depth_scaling() -> String {
    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let mut t = Table::new("Depth scalability — F64, T = 64 (latency ms; ratio vs D2)")
        .header(&["Depth", "FPGA(sim)", "ratio", "CPU(model)", "ratio", "GPU(model)", "ratio"]);
    let base: Vec<f64> = {
        let topo = Topology::new(64, 2).unwrap();
        vec![
            fpga_latency_ms(&topo, 64),
            cpu.latency_ms(&topo, 64),
            gpu.latency_ms(&topo, 64),
        ]
    };
    for d in [2usize, 4, 6, 8, 10] {
        let Ok(topo) = Topology::new(64, d) else { continue };
        let f = fpga_latency_ms(&topo, 64);
        let c = cpu.latency_ms(&topo, 64);
        let g = gpu.latency_ms(&topo, 64);
        t.row(vec![
            format!("D{d}"),
            ms(f),
            format!("x{:.2}", f / base[0]),
            ms(c),
            format!("x{:.2}", c / base[1]),
            ms(g),
            format!("x{:.2}", g / base[2]),
        ]);
    }
    let mut s = t.render();
    s.push_str("Paper (D2→D6, T=64): CPU x2.9, GPU x2.2, FPGA ~x1.4.\n");
    s
}

/// Latency-vs-T scaling series (§4.2 discussion of RH_m's effect).
pub fn latency_scaling() -> String {
    let mut t = Table::new("Latency scaling with sequence length (FPGA sim, ms)")
        .header(&["T", "F32-D2 (RH_m=1)", "F64-D2 (RH_m=4)", "F32-D6 (RH_m=1)", "F64-D6 (RH_m=8)"]);
    for &steps in &[1usize, 2, 4, 6, 16, 32, 64, 128, 256] {
        let row: Vec<String> = ["F32-D2", "F64-D2", "F32-D6", "F64-D6"]
            .iter()
            .map(|name| ms(fpga_latency_ms(&Topology::from_name(name).unwrap(), steps)))
            .collect();
        let mut cells = vec![steps.to_string()];
        cells.extend(row);
        t.row(cells);
    }
    t.render()
}

/// Shape checks comparing our regenerated tables to the paper, used by
/// tests and EXPERIMENTS.md. Returns (check name, ok, detail) triples.
pub fn shape_checks() -> Vec<(String, bool, String)> {
    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let mut checks = Vec::new();
    // 1. FPGA (incl. platform overhead) beats calibrated CPU and GPU in
    //    every Table-2 cell — the paper's "lowest overall latency in all
    //    scenarios".
    let mut all_win = true;
    let mut detail = String::new();
    for col in &paper_data::TABLE2 {
        let topo = Topology::from_name(col.model).unwrap();
        for &t in &paper_data::TIMESTEPS {
            let f = fpga_platform_latency_ms(&topo, t);
            let c = cpu.latency_ms(&topo, t);
            let g = gpu.latency_ms(&topo, t);
            if f >= c || f >= g {
                all_win = false;
                detail = format!("{} T={t}: fpga {f:.3} cpu {c:.3} gpu {g:.3}", col.model);
            }
        }
    }
    checks.push(("fpga_wins_every_cell".into(), all_win, detail));
    // 2. Speedup ordering: D6 speedups exceed D2 speedups at same width/T.
    let su = |name: &str, t: usize| {
        let topo = Topology::from_name(name).unwrap();
        cpu.latency_ms(&topo, t) / fpga_platform_latency_ms(&topo, t)
    };
    let ok2 = su("F32-D6", 64) > su("F32-D2", 64);
    checks.push((
        "depth_increases_cpu_speedup".into(),
        ok2,
        format!("D6 {:.1}x vs D2 {:.1}x", su("F32-D6", 64), su("F32-D2", 64)),
    ));
    // 3. FPGA latency ratio D6/D2 well below CPU's (depth scalability;
    //    paper: ~1.4x vs 2.9x).
    let f_ratio = fpga_platform_latency_ms(&Topology::from_name("F64-D6").unwrap(), 64)
        / fpga_platform_latency_ms(&Topology::from_name("F64-D2").unwrap(), 64);
    let c_ratio = cpu.latency_ms(&Topology::from_name("F64-D6").unwrap(), 64)
        / cpu.latency_ms(&Topology::from_name("F64-D2").unwrap(), 64);
    checks.push((
        "fpga_depth_ratio_below_cpu".into(),
        f_ratio < 0.7 * c_ratio,
        format!("fpga x{f_ratio:.2} vs cpu x{c_ratio:.2}"),
    ));
    // 4. Energy: FPGA at least 10x better than GPU model everywhere.
    let dev = FpgaDevice::ZCU104;
    let mut ok4 = true;
    let mut det4 = String::new();
    for col in &paper_data::TABLE2 {
        let topo = Topology::from_name(col.model).unwrap();
        let cfg = BalancedConfig::paper_config(&topo);
        let p_fpga = fpga_power_w(&estimate(&cfg).pct(&dev), &dev);
        for &t in &paper_data::TIMESTEPS {
            let e_f = energy_per_timestep_mj(p_fpga, fpga_latency_ms(&topo, t), t);
            let e_g = gpu.energy_per_timestep_mj(&topo, t);
            if e_g / e_f < 3.0 {
                ok4 = false;
                det4 = format!("{} T={t}: {:.1}x", col.model, e_g / e_f);
            }
        }
    }
    checks.push(("fpga_energy_beats_gpu".into(), ok4, det4));
    checks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        assert!(table1().contains("LSTM-AE-F64-D6"));
        assert!(table2(None).contains("Table 2"));
        assert!(table3().contains("Energy per timestep"));
        assert!(depth_scaling().contains("D10"));
        assert!(latency_scaling().contains("256"));
    }

    #[test]
    fn all_shape_checks_pass() {
        for (name, ok, detail) in shape_checks() {
            assert!(ok, "shape check {name} failed: {detail}");
        }
    }

    #[test]
    fn sim_latency_shape_tracks_paper_fpga_column() {
        // Our platform-adjusted latency should correlate with the paper's
        // FPGA column: same slowest model at T=64, and T-scaling ratios
        // within ~3x of the paper's (kernel cycles are exact per Eq 1;
        // the board's DMA/driver behaviour is a one-constant model).
        let at64: Vec<f64> = paper_data::TABLE2
            .iter()
            .map(|c| fpga_platform_latency_ms(&Topology::from_name(c.model).unwrap(), 64))
            .collect();
        let paper64: Vec<f64> = paper_data::TABLE2.iter().map(|c| c.fpga[5]).collect();
        let argmax = |v: &[f64]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        assert_eq!(argmax(&at64), argmax(&paper64));
        // Scaling ratio T=64/T=1 within a factor ~3 of the paper's.
        for c in &paper_data::TABLE2 {
            let topo = Topology::from_name(c.model).unwrap();
            let ours = fpga_platform_latency_ms(&topo, 64) / fpga_platform_latency_ms(&topo, 1);
            let paper = c.fpga[5] / c.fpga[0];
            let rel = ours / paper;
            assert!(
                (0.3..3.5).contains(&rel),
                "{}: ours x{ours:.1} paper x{paper:.1}",
                c.model
            );
        }
    }
}
