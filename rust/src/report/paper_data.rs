//! The paper's published evaluation numbers, embedded verbatim for
//! (a) calibrating the CPU/GPU analytical baselines and (b) printing
//! paper-vs-measured columns in every regenerated table.
//!
//! Source: Tables 1–3 of "Exploiting temporal parallelism for LSTM
//! Autoencoder acceleration on FPGA". Table 3's D6 sub-table is partially
//! garbled in the available text; cells marked `derived` are reconstructed
//! via the paper's own formula `E/t = P · latency / T` from Table 2
//! latencies and the §4.2 power bands (CPU 255–265 W, GPU 35–40 W,
//! FPGA 11–12 W) — the legible cells validate that reconstruction to
//! within a few percent (see tests).

/// Sequence lengths evaluated in Tables 2–3.
pub const TIMESTEPS: [usize; 6] = [1, 2, 4, 6, 16, 64];

/// Model order used throughout the paper's tables.
pub const MODELS: [&str; 4] =
    ["LSTM-AE-F32-D2", "LSTM-AE-F64-D2", "LSTM-AE-F32-D6", "LSTM-AE-F64-D6"];

/// Table 1: (model, RH_m, LUT%, FF%, BRAM%, DSP%).
pub const TABLE1: [(&str, u64, f64, f64, f64, f64); 4] = [
    ("LSTM-AE-F32-D2", 1, 26.11, 12.87, 39.74, 34.72),
    ("LSTM-AE-F64-D2", 4, 43.04, 18.52, 77.08, 18.06),
    ("LSTM-AE-F32-D6", 1, 42.47, 16.89, 69.39, 48.15),
    ("LSTM-AE-F64-D6", 8, 69.27, 24.19, 59.94, 16.67),
];

/// One platform's latency column: ms at T = 1, 2, 4, 6, 16, 64.
#[derive(Clone, Copy, Debug)]
pub struct LatencyColumn {
    pub model: &'static str,
    pub fpga: [f64; 6],
    pub cpu: [f64; 6],
    pub gpu: [f64; 6],
}

/// Table 2: inference latency (ms), average over 1000 inferences.
pub const TABLE2: [LatencyColumn; 4] = [
    LatencyColumn {
        model: "LSTM-AE-F32-D2",
        fpga: [0.033, 0.036, 0.037, 0.038, 0.048, 0.086],
        cpu: [0.420, 0.479, 0.550, 0.591, 0.887, 2.480],
        gpu: [0.275, 0.273, 0.269, 0.274, 0.288, 0.359],
    },
    LatencyColumn {
        model: "LSTM-AE-F64-D2",
        fpga: [0.038, 0.050, 0.059, 0.069, 0.118, 0.350],
        cpu: [0.414, 0.542, 0.613, 0.596, 0.923, 2.513],
        gpu: [0.272, 0.273, 0.279, 0.279, 0.293, 0.412],
    },
    LatencyColumn {
        model: "LSTM-AE-F32-D6",
        fpga: [0.038, 0.036, 0.038, 0.038, 0.051, 0.089],
        cpu: [1.155, 1.341, 1.643, 1.873, 2.620, 7.080],
        gpu: [0.659, 0.655, 0.668, 0.671, 0.710, 0.888],
    },
    LatencyColumn {
        model: "LSTM-AE-F64-D6",
        fpga: [0.060, 0.066, 0.079, 0.093, 0.161, 0.474],
        cpu: [1.208, 1.551, 1.774, 1.794, 2.697, 7.218],
        gpu: [0.664, 0.663, 0.674, 0.672, 0.701, 0.902],
    },
];

/// Legible Table-3 cells (mJ/timestep) used to validate the derived
/// reconstruction: (model, T, fpga, cpu, gpu).
pub const TABLE3_LEGIBLE: [(&str, usize, f64, f64, f64); 8] = [
    ("LSTM-AE-F32-D2", 1, 0.362, 107.409, 9.869),
    ("LSTM-AE-F32-D2", 4, 0.101, 35.670, 2.430),
    ("LSTM-AE-F32-D2", 64, 0.016, 10.098, 0.204),
    ("LSTM-AE-F64-D2", 1, 0.435, 108.196, 9.873),
    ("LSTM-AE-F64-D2", 16, 0.088, 14.884, 0.671),
    ("LSTM-AE-F32-D6", 1, 0.426, 305.307, 24.002),
    ("LSTM-AE-F32-D6", 2, 0.201, 179.089, 11.912),
    ("LSTM-AE-F64-D6", 1, 0.677, 320.644, 24.189),
];

/// Effective platform powers implied by the legible Table-3 cells
/// (E·T/latency); within the §4.2 bands.
pub const PAPER_FPGA_POWER_W: f64 = 11.3;
pub const PAPER_CPU_POWER_W: f64 = 260.0;
pub const PAPER_GPU_POWER_W: f64 = 36.2;

/// Look up a Table-2 column by (possibly short) model name.
pub fn table2(model: &str) -> Option<&'static LatencyColumn> {
    let full = if model.starts_with("LSTM-AE-") {
        model.to_string()
    } else {
        format!("LSTM-AE-{model}")
    };
    TABLE2.iter().find(|c| c.model == full)
}

/// Paper Table-3 value derived from Table-2 latency (the paper's own
/// E = P·lat/T arithmetic). `platform` ∈ {"fpga", "cpu", "gpu"}.
pub fn table3_derived(model: &str, t_index: usize, platform: &str) -> Option<f64> {
    let col = table2(model)?;
    let t = TIMESTEPS[t_index];
    let (lat, p) = match platform {
        "fpga" => (col.fpga[t_index], PAPER_FPGA_POWER_W),
        "cpu" => (col.cpu[t_index], PAPER_CPU_POWER_W),
        "gpu" => (col.gpu[t_index], PAPER_GPU_POWER_W),
        _ => return None,
    };
    Some(p * lat / t as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_speedups_match_abstract() {
        // "latency speedups up to 79.6x vs. CPU and 18.2x vs. GPU".
        let mut max_cpu: f64 = 0.0;
        let mut max_gpu: f64 = 0.0;
        for col in &TABLE2 {
            for i in 0..6 {
                max_cpu = max_cpu.max(col.cpu[i] / col.fpga[i]);
                max_gpu = max_gpu.max(col.gpu[i] / col.fpga[i]);
            }
        }
        assert!((max_cpu - 79.6).abs() < 0.5, "max CPU speedup {max_cpu}");
        assert!((max_gpu - 18.2).abs() < 0.2, "max GPU speedup {max_gpu}");
    }

    #[test]
    fn derived_table3_matches_legible_cells() {
        for (model, t, fpga, cpu, gpu) in TABLE3_LEGIBLE {
            let ti = TIMESTEPS.iter().position(|&x| x == t).unwrap();
            let check = |platform: &str, paper: f64| {
                let d = table3_derived(model, ti, platform).unwrap();
                let rel = (d - paper).abs() / paper;
                assert!(
                    rel < 0.08,
                    "{model} T={t} {platform}: derived {d:.3} paper {paper} ({rel:.2})"
                );
            };
            check("fpga", fpga);
            check("cpu", cpu);
            check("gpu", gpu);
        }
    }

    #[test]
    fn depth_scaling_claim_from_table2() {
        // §4.2: F64 D2→D6 at T=64: CPU ~2.9x, GPU ~2.2x, FPGA ~1.4x.
        let d2 = table2("F64-D2").unwrap();
        let d6 = table2("F64-D6").unwrap();
        assert!((d6.cpu[5] / d2.cpu[5] - 2.9).abs() < 0.1);
        assert!((d6.gpu[5] / d2.gpu[5] - 2.2).abs() < 0.1);
        assert!((d6.fpga[5] / d2.fpga[5] - 1.4).abs() < 0.1);
    }

    #[test]
    fn lookup_by_short_name() {
        assert!(table2("F32-D6").is_some());
        assert!(table2("F99-D2").is_none());
    }
}
