//! Table/figure regeneration harness: produces the paper's Tables 1–3
//! and the §4.2 scaling figures, with paper-published values printed
//! alongside our measured/modelled values for shape comparison.

pub mod paper_data;
pub mod tables;

pub use tables::{table1, table2, table3, depth_scaling, latency_scaling};
