//! LSTM cell golden models.
//!
//! Two implementations of the paper's Figure-1 equations:
//!
//! - [`lstm_step_f32`] — plain f32, the reference the JAX model matches.
//! - [`QuantLstmCell`] — the Q8.24 + PWL datapath, bit-accurate to the
//!   FPGA's MVM/activation units (wide MAC accumulation, single rounding
//!   per dot product, saturating element-wise ops). The dataflow simulator
//!   uses this for functional output.
//!
//! Gate order everywhere: `i, f, g, o` (input, forget, candidate, output).

use crate::activations::Pwl;
use crate::fixed::Q8_24;

use super::weights::{LayerWeights, QuantLayerWeights};

/// State carried between timesteps: hidden and cell vectors.
#[derive(Clone, Debug, Default)]
pub struct LstmState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

impl LstmState {
    pub fn zeros(lh: usize) -> LstmState {
        LstmState { h: vec![0.0; lh], c: vec![0.0; lh] }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// One f32 LSTM timestep. `x` has `dims.lx` features; returns the new
/// state. Matches `python/compile/kernels/ref.py` exactly (same op order,
/// f32 throughout) up to platform libm differences in exp/tanh.
pub fn lstm_step_f32(w: &LayerWeights, state: &LstmState, x: &[f32]) -> LstmState {
    let lh = w.dims.lh;
    let lx = w.dims.lx;
    assert_eq!(x.len(), lx, "input width");
    assert_eq!(state.h.len(), lh, "state width");
    let mut h = vec![0.0f32; lh];
    let mut c = vec![0.0f32; lh];
    for j in 0..lh {
        // The four gate pre-activations for output element j.
        let mut pre = [0.0f32; 4];
        for (g, p) in pre.iter_mut().enumerate() {
            let row = g * lh + j;
            let mut acc_x = 0.0f32;
            for k in 0..lx {
                acc_x += w.wx[row * lx + k] * x[k];
            }
            let mut acc_h = 0.0f32;
            for k in 0..lh {
                acc_h += w.wh[row * lh + k] * state.h[k];
            }
            *p = (acc_x + w.bx[row]) + (acc_h + w.bh[row]);
        }
        let i = sigmoid(pre[0]);
        let f = sigmoid(pre[1]);
        let g = pre[2].tanh();
        let o = sigmoid(pre[3]);
        c[j] = f * state.c[j] + i * g;
        h[j] = o * c[j].tanh();
    }
    LstmState { h, c }
}

/// Quantized state on the Q8.24 grid.
#[derive(Clone, Debug)]
pub struct QuantLstmState {
    pub h: Vec<Q8_24>,
    pub c: Vec<Q8_24>,
}

impl QuantLstmState {
    pub fn zeros(lh: usize) -> QuantLstmState {
        QuantLstmState { h: vec![Q8_24::ZERO; lh], c: vec![Q8_24::ZERO; lh] }
    }

    pub fn h_f32(&self) -> Vec<f32> {
        self.h.iter().map(|q| q.to_f32()).collect()
    }
}

/// The FPGA datapath model for one LSTM layer: quantized weights + shared
/// PWL tables. Construct once, step per timestep.
pub struct QuantLstmCell {
    pub w: QuantLayerWeights,
    sigmoid: Pwl,
    tanh: Pwl,
}

impl QuantLstmCell {
    pub fn new(w: &LayerWeights) -> QuantLstmCell {
        QuantLstmCell { w: w.quantized(), sigmoid: Pwl::sigmoid(), tanh: Pwl::tanh() }
    }

    /// One timestep in the Q8.24 datapath. MVM accumulation is wide
    /// (2^48 scale) with a single rounding per dot product — matching the
    /// DSP cascade in the MVM units — and all element-wise ops saturate.
    ///
    /// Row dot products run over contiguous slices with iterator zips so
    /// LLVM can elide bounds checks and vectorize the i32×i32→i64 MACs
    /// (≈1.9x over the original indexed loops; EXPERIMENTS.md §Perf).
    pub fn step(&self, state: &QuantLstmState, x: &[Q8_24]) -> QuantLstmState {
        let lh = self.w.dims.lh;
        let lx = self.w.dims.lx;
        assert_eq!(x.len(), lx);
        assert_eq!(state.h.len(), lh);
        // Gate pre-activations for all 4·LH rows, row-contiguous.
        let mut pre = vec![Q8_24::ZERO; 4 * lh];
        for (row, p) in pre.iter_mut().enumerate() {
            let wx_row = &self.w.wx[row * lx..(row + 1) * lx];
            let acc_x: i64 =
                wx_row.iter().zip(x).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
            let wh_row = &self.w.wh[row * lh..(row + 1) * lh];
            let acc_h: i64 =
                wh_row.iter().zip(&state.h).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
            // (Wx·x + bx) + (Wh·h + bh), rounded once per MVM as the
            // hardware does at the accumulator output.
            let mx = Q8_24::from_wide(acc_x).add(self.w.bx[row]);
            let mh = Q8_24::from_wide(acc_h).add(self.w.bh[row]);
            *p = mx.add(mh);
        }
        let mut h = vec![Q8_24::ZERO; lh];
        let mut c = vec![Q8_24::ZERO; lh];
        for j in 0..lh {
            let i = self.sigmoid.eval_q(pre[j]);
            let f = self.sigmoid.eval_q(pre[lh + j]);
            let g = self.tanh.eval_q(pre[2 * lh + j]);
            let o = self.sigmoid.eval_q(pre[3 * lh + j]);
            c[j] = f.mul(state.c[j]).add(i.mul(g));
            h[j] = o.mul(self.tanh.eval_q(c[j]));
        }
        QuantLstmState { h, c }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::LayerDims;
    use crate::util::prop::props;
    use crate::util::rng::Xoshiro256;

    fn mk(lx: usize, lh: usize, seed: u64) -> LayerWeights {
        LayerWeights::random(LayerDims { lx, lh }, &mut Xoshiro256::seeded(seed))
    }

    #[test]
    fn f32_step_shapes() {
        let w = mk(32, 16, 1);
        let s = lstm_step_f32(&w, &LstmState::zeros(16), &vec![0.1; 32]);
        assert_eq!(s.h.len(), 16);
        assert_eq!(s.c.len(), 16);
    }

    #[test]
    fn outputs_bounded_by_gates() {
        // |h| <= 1 always (o in [0,1], tanh(c) in [-1,1]).
        props("h_bounded", 64, |g| {
            let w = mk(8, 8, g.case as u64);
            let x: Vec<f32> = g.vec_f32(8, -3.0, 3.0);
            let mut s = LstmState::zeros(8);
            for _ in 0..5 {
                s = lstm_step_f32(&w, &s, &x);
            }
            assert!(s.h.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        });
    }

    #[test]
    fn zero_everything_is_zero_h() {
        // With zero weights and biases, i=f=o=0.5, g=0 ⇒ c=0, h=0.
        let mut w = mk(4, 4, 3);
        w.wx.iter_mut().for_each(|v| *v = 0.0);
        w.wh.iter_mut().for_each(|v| *v = 0.0);
        w.bx.iter_mut().for_each(|v| *v = 0.0);
        w.bh.iter_mut().for_each(|v| *v = 0.0);
        let s = lstm_step_f32(&w, &LstmState::zeros(4), &[1.0, -1.0, 2.0, 0.5]);
        assert!(s.h.iter().all(|v| v.abs() < 1e-7), "{:?}", s.h);
        assert!(s.c.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn quant_tracks_f32_closely() {
        // Q8.24 + PWL vs f32: error per step is dominated by the PWL
        // approximation (~1.5e-3 on tanh), not quantization.
        props("quant_vs_f32", 24, |g| {
            let w = mk(16, 16, g.case as u64 + 100);
            let cell = QuantLstmCell::new(&w);
            let x: Vec<f32> = g.vec_f32(16, -1.0, 1.0);
            let xq: Vec<Q8_24> = x.iter().map(|&v| Q8_24::from_f32(v)).collect();
            let mut sf = LstmState::zeros(16);
            let mut sq = QuantLstmState::zeros(16);
            for _ in 0..8 {
                sf = lstm_step_f32(&w, &sf, &x);
                sq = cell.step(&sq, &xq);
            }
            for (a, b) in sf.h.iter().zip(sq.h_f32()) {
                assert!((a - b).abs() < 0.02, "f32 {a} vs quant {b}");
            }
        });
    }

    #[test]
    fn quant_step_deterministic() {
        let w = mk(8, 8, 5);
        let cell = QuantLstmCell::new(&w);
        let x: Vec<Q8_24> = (0..8).map(|i| Q8_24::from_f64(i as f64 * 0.1 - 0.4)).collect();
        let a = cell.step(&QuantLstmState::zeros(8), &x);
        let b = cell.step(&QuantLstmState::zeros(8), &x);
        assert_eq!(a.h, b.h);
        assert_eq!(a.c, b.c);
    }

    #[test]
    fn quant_h_bounded_by_one() {
        props("quant_h_bound", 16, |g| {
            let w = mk(8, 8, g.case as u64 + 300);
            let cell = QuantLstmCell::new(&w);
            let x: Vec<Q8_24> =
                (0..8).map(|_| Q8_24::from_f64(g.f64_in(-5.0, 5.0))).collect();
            let mut s = QuantLstmState::zeros(8);
            for _ in 0..10 {
                s = cell.step(&s, &x);
            }
            for h in &s.h {
                assert!(h.to_f64().abs() <= 1.0 + 1e-6);
            }
        });
    }
}
