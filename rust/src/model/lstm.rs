//! LSTM cell golden models.
//!
//! Two implementations of the paper's Figure-1 equations:
//!
//! - [`lstm_step_f32`] — plain f32, the reference the JAX model matches.
//! - [`QuantLstmCell`] — the Q8.24 + PWL datapath, bit-accurate to the
//!   FPGA's MVM/activation units (wide MAC accumulation, single rounding
//!   per dot product, saturating element-wise ops). The dataflow simulator
//!   uses this for functional output.
//!
//! The quantized kernels run over a **gate-interleaved weight layout**
//! (`[j][k][4]`, see `QuantLayerWeights`) so the four gate dot products of
//! one output element share a single streaming pass over `x`/`h`; the
//! pre-interleave row-major kernels are kept as `*_rowmajor` reference
//! oracles. Reusable buffers live in [`StepScratch`] / [`ScratchArena`]
//! (per-worker, grow-only, write-before-read).
//!
//! Gate order everywhere: `i, f, g, o` (input, forget, candidate, output).

use std::cell::RefCell;

use crate::activations::Pwl;
use crate::fixed::Q8_24;

use super::weights::{LayerWeights, QuantLayerWeights};

/// State carried between timesteps: hidden and cell vectors.
#[derive(Clone, Debug, Default)]
pub struct LstmState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

impl LstmState {
    pub fn zeros(lh: usize) -> LstmState {
        LstmState { h: vec![0.0; lh], c: vec![0.0; lh] }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// One f32 LSTM timestep. `x` has `dims.lx` features; returns the new
/// state. Matches `python/compile/kernels/ref.py` exactly (same op order,
/// f32 throughout) up to platform libm differences in exp/tanh.
pub fn lstm_step_f32(w: &LayerWeights, state: &LstmState, x: &[f32]) -> LstmState {
    let lh = w.dims.lh;
    let lx = w.dims.lx;
    assert_eq!(x.len(), lx, "input width");
    assert_eq!(state.h.len(), lh, "state width");
    let mut h = vec![0.0f32; lh];
    let mut c = vec![0.0f32; lh];
    for j in 0..lh {
        // The four gate pre-activations for output element j.
        let mut pre = [0.0f32; 4];
        for (g, p) in pre.iter_mut().enumerate() {
            let row = g * lh + j;
            let mut acc_x = 0.0f32;
            for k in 0..lx {
                acc_x += w.wx[row * lx + k] * x[k];
            }
            let mut acc_h = 0.0f32;
            for k in 0..lh {
                acc_h += w.wh[row * lh + k] * state.h[k];
            }
            *p = (acc_x + w.bx[row]) + (acc_h + w.bh[row]);
        }
        let i = sigmoid(pre[0]);
        let f = sigmoid(pre[1]);
        let g = pre[2].tanh();
        let o = sigmoid(pre[3]);
        c[j] = f * state.c[j] + i * g;
        h[j] = o * c[j].tanh();
    }
    LstmState { h, c }
}

/// Quantized state on the Q8.24 grid.
#[derive(Clone, Debug, Default)]
pub struct QuantLstmState {
    pub h: Vec<Q8_24>,
    pub c: Vec<Q8_24>,
}

impl QuantLstmState {
    pub fn zeros(lh: usize) -> QuantLstmState {
        QuantLstmState { h: vec![Q8_24::ZERO; lh], c: vec![Q8_24::ZERO; lh] }
    }

    /// Re-zero in place for a new sequence (or a new layer width),
    /// reusing the allocations — the t=0 reset of the engine hot path.
    pub fn reset(&mut self, lh: usize) {
        self.h.clear();
        self.h.resize(lh, Q8_24::ZERO);
        self.c.clear();
        self.c.resize(lh, Q8_24::ZERO);
    }

    pub fn h_f32(&self) -> Vec<f32> {
        self.h.iter().map(|q| q.to_f32()).collect()
    }
}

/// Caller-owned scratch for the allocation-free step paths
/// ([`QuantLstmCell::step_into`] / [`QuantLstmCell::step_batch_into`]):
/// holds the `4·LH` (or `B·4·LH`) gate pre-activation buffer so repeated
/// timesteps reuse one allocation. Construct once per worker/stream and
/// pass to every step; it grows to the largest layer it has seen and
/// never shrinks.
#[derive(Default)]
pub struct StepScratch {
    pre: Vec<Q8_24>,
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch { pre: Vec::new() }
    }

    /// The pre-activation buffer sized to `n` entries, **without** zeroing.
    ///
    /// Write-before-read invariant: every kernel that borrows this buffer
    /// fully writes `pre[..n]` in its MVM phase before the element-wise
    /// phase reads any of it, so stale values from earlier timesteps (or
    /// other layer widths, or the other kernel layout) are never observed.
    /// The previous `clear()+resize()` re-zeroed `4·LH` (or `B·4·LH`)
    /// entries on every timestep for nothing; this only pays a fill when
    /// the buffer grows. Any new kernel taking a `StepScratch` must keep
    /// the invariant.
    fn pre(&mut self, n: usize) -> &mut [Q8_24] {
        if self.pre.len() < n {
            self.pre.resize(n, Q8_24::ZERO);
        }
        &mut self.pre[..n]
    }
}

/// Per-worker scratch arena: every reusable buffer on the engine hot paths
/// in one place, so a pipeline-stage worker, batch-engine call, or
/// convenience-wrapper caller does zero steady-state allocation.
///
/// Field groups (all grow-only, reused across calls):
/// - `step` — the kernel pre-activation scratch ([`StepScratch`]).
/// - `state` — a recurrent h/c state for sequential forward passes.
/// - `h`/`c` — the batch engine's `[B][LH]` state planes.
/// - `cur`/`next` — the batch engine's `[T][B][width]` activation
///   double-buffer.
///
/// Fields are public so callers can split-borrow them in one expression,
/// e.g. `cell.step_batch_into(b, &mut a.h, &mut a.c, &a.cur, &mut a.step)`.
#[derive(Default)]
pub struct ScratchArena {
    pub step: StepScratch,
    pub state: QuantLstmState,
    pub h: Vec<Q8_24>,
    pub c: Vec<Q8_24>,
    pub cur: Vec<Q8_24>,
    pub next: Vec<Q8_24>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }
}

thread_local! {
    static THREAD_ARENA: RefCell<ScratchArena> = RefCell::new(ScratchArena::new());
}

/// Run `f` with this thread's shared [`ScratchArena`].
///
/// The allocating convenience paths ([`QuantLstmCell::step`],
/// `engine::forward_in_place`, the batch engine's public entry) borrow the
/// arena through here so repeated calls on one thread reuse one set of
/// buffers instead of reallocating per call. A re-entrant call (an `f`
/// that itself reaches `with_thread_arena` again) gets a fresh temporary
/// arena rather than a `RefCell` borrow panic.
pub fn with_thread_arena<R>(f: impl FnOnce(&mut ScratchArena) -> R) -> R {
    THREAD_ARENA.with(|cell| match cell.try_borrow_mut() {
        Ok(mut arena) => f(&mut arena),
        Err(_) => f(&mut ScratchArena::new()),
    })
}

/// Four-lane fused MAC: the four gate dot products for one output element
/// `j`, fed by a single streaming pass over `x` and `h` against the
/// gate-interleaved weight block for `j` (`[k][4]` chunks). Per-gate
/// accumulation is wide (2^48 scale) with a single rounding per dot
/// product, and the `(Wx·x + bx) + (Wh·h + bh)` combination order matches
/// the row-major reference exactly — integer MACs are exact and each
/// gate's partial sums run in the same `k` order, so the result is
/// bit-identical to four separate row dot products.
#[inline]
fn fused_rows(
    wxj: &[Q8_24],
    whj: &[Q8_24],
    bx4: &[Q8_24],
    bh4: &[Q8_24],
    x: &[Q8_24],
    h: &[Q8_24],
    out: &mut [Q8_24],
) {
    let mut ax = [0i64; 4];
    for (w4, v) in wxj.chunks_exact(4).zip(x) {
        let v = v.0 as i64;
        ax[0] += w4[0].0 as i64 * v;
        ax[1] += w4[1].0 as i64 * v;
        ax[2] += w4[2].0 as i64 * v;
        ax[3] += w4[3].0 as i64 * v;
    }
    let mut ah = [0i64; 4];
    for (w4, v) in whj.chunks_exact(4).zip(h) {
        let v = v.0 as i64;
        ah[0] += w4[0].0 as i64 * v;
        ah[1] += w4[1].0 as i64 * v;
        ah[2] += w4[2].0 as i64 * v;
        ah[3] += w4[3].0 as i64 * v;
    }
    for g in 0..4 {
        let mx = Q8_24::from_wide(ax[g]).add(bx4[g]);
        let mh = Q8_24::from_wide(ah[g]).add(bh4[g]);
        out[g] = mx.add(mh);
    }
}

/// Element-wise gate phase over a `[j][4]` gate-minor pre-activation
/// buffer: `c[j] = f·c[j] + i·g`, `h[j] = o·tanh(c[j])`, all saturating.
#[inline]
fn gates_apply(sigmoid: &Pwl, tanh: &Pwl, pre: &[Q8_24], h: &mut [Q8_24], c: &mut [Q8_24]) {
    for ((p, cj), hj) in pre.chunks_exact(4).zip(c.iter_mut()).zip(h.iter_mut()) {
        let i = sigmoid.eval_q(p[0]);
        let f = sigmoid.eval_q(p[1]);
        let g = tanh.eval_q(p[2]);
        let o = sigmoid.eval_q(p[3]);
        *cj = f.mul(*cj).add(i.mul(g));
        *hj = o.mul(tanh.eval_q(*cj));
    }
}

/// Batch-tile width for [`QuantLstmCell::step_batch_into`]: the MVM phase
/// is blocked over `B` in tiles of this many windows so a tile's `x`/`h`
/// rows stay L1-resident across all `LH` interleaved weight blocks, while
/// each weight block is streamed once per tile rather than once per
/// window.
const BATCH_TILE: usize = 8;

/// The FPGA datapath model for one LSTM layer: quantized weights + shared
/// PWL tables. Construct once, step per timestep.
pub struct QuantLstmCell {
    pub w: QuantLayerWeights,
    sigmoid: Pwl,
    tanh: Pwl,
}

impl QuantLstmCell {
    pub fn new(w: &LayerWeights) -> QuantLstmCell {
        QuantLstmCell { w: w.quantized(), sigmoid: Pwl::sigmoid(), tanh: Pwl::tanh() }
    }

    /// One timestep in the Q8.24 datapath. MVM accumulation is wide
    /// (2^48 scale) with a single rounding per dot product — matching the
    /// DSP cascade in the MVM units — and all element-wise ops saturate.
    ///
    /// Allocating convenience wrapper over [`Self::step_into`]; the
    /// serving hot paths (engine, simulator functional pass) use
    /// `step_into` directly with reused buffers. The pre-activation
    /// scratch comes from the thread-local [`ScratchArena`] (see
    /// [`with_thread_arena`]), so repeated `step` calls — the simulator's
    /// functional pass, doctests, examples — stop paying a fresh
    /// allocation per timestep; only the returned state allocates.
    pub fn step(&self, state: &QuantLstmState, x: &[Q8_24]) -> QuantLstmState {
        let mut next = state.clone();
        with_thread_arena(|arena| self.step_into(&mut next, x, &mut arena.step));
        next
    }

    /// One timestep, in place and allocation-free: updates `state.h` /
    /// `state.c` directly using the caller-owned `scratch` for the gate
    /// pre-activations. Bit-identical to [`Self::step`] (which delegates
    /// here): the MVM phase reads `state.h` to completion before the
    /// element-wise phase overwrites it, and `c[j]` is read before
    /// written within each element — the same read/write discipline the
    /// FPGA datapath has between its MVM and activation stages.
    ///
    /// The MVM phase runs over the gate-interleaved layout
    /// (`QuantLayerWeights::wx_il`/`wh_il`): for each output element `j`,
    /// one streaming pass over `x` and one over `h` feed all four gate
    /// dot products via [`fused_rows`], so `x`/`h` are read once per
    /// element instead of four times and the inner loop presents four
    /// contiguous i32 lanes to the autovectorizer. Bit-identical to the
    /// row-major reference ([`Self::step_into_rowmajor`]) — enforced by
    /// the layout-equivalence property suite.
    pub fn step_into(&self, state: &mut QuantLstmState, x: &[Q8_24], scratch: &mut StepScratch) {
        let lh = self.w.dims.lh;
        let lx = self.w.dims.lx;
        assert_eq!(x.len(), lx);
        assert_eq!(state.h.len(), lh);
        assert_eq!(state.c.len(), lh);
        // Gate pre-activations, `[j][4]` gate-minor; fully written below
        // before `gates_apply` reads them (scratch is not zeroed).
        let pre = scratch.pre(4 * lh);
        for j in 0..lh {
            fused_rows(
                &self.w.wx_il[j * 4 * lx..(j + 1) * 4 * lx],
                &self.w.wh_il[j * 4 * lh..(j + 1) * 4 * lh],
                &self.w.bx_il[j * 4..j * 4 + 4],
                &self.w.bh_il[j * 4..j * 4 + 4],
                x,
                &state.h,
                &mut pre[j * 4..j * 4 + 4],
            );
        }
        gates_apply(&self.sigmoid, &self.tanh, pre, &mut state.h, &mut state.c);
    }

    /// Row-major reference kernel: the pre-interleave implementation, kept
    /// as the layout-equivalence oracle for the property suite and as the
    /// baseline row in `benches/hotpath.rs`. Arithmetic is identical to
    /// [`Self::step_into`] (same per-gate MAC order, same rounding and
    /// combination discipline); only the weight traversal differs.
    pub fn step_into_rowmajor(
        &self,
        state: &mut QuantLstmState,
        x: &[Q8_24],
        scratch: &mut StepScratch,
    ) {
        let lh = self.w.dims.lh;
        let lx = self.w.dims.lx;
        assert_eq!(x.len(), lx);
        assert_eq!(state.h.len(), lh);
        assert_eq!(state.c.len(), lh);
        // Gate pre-activations for all 4·LH rows, row-contiguous; fully
        // written before the element-wise loop reads them.
        let pre = scratch.pre(4 * lh);
        for (row, p) in pre.iter_mut().enumerate() {
            let wx_row = &self.w.wx[row * lx..(row + 1) * lx];
            let acc_x: i64 =
                wx_row.iter().zip(x).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
            let wh_row = &self.w.wh[row * lh..(row + 1) * lh];
            let acc_h: i64 =
                wh_row.iter().zip(&state.h).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
            // (Wx·x + bx) + (Wh·h + bh), rounded once per MVM as the
            // hardware does at the accumulator output.
            let mx = Q8_24::from_wide(acc_x).add(self.w.bx[row]);
            let mh = Q8_24::from_wide(acc_h).add(self.w.bh[row]);
            *p = mx.add(mh);
        }
        for j in 0..lh {
            let i = self.sigmoid.eval_q(pre[j]);
            let f = self.sigmoid.eval_q(pre[lh + j]);
            let g = self.tanh.eval_q(pre[2 * lh + j]);
            let o = self.sigmoid.eval_q(pre[3 * lh + j]);
            state.c[j] = f.mul(state.c[j]).add(i.mul(g));
            state.h[j] = o.mul(self.tanh.eval_q(state.c[j]));
        }
    }

    /// `B` independent windows stepped through this layer at once — the
    /// MVM → MMM restructure of the throughput path, over the
    /// gate-interleaved layout and blocked over `B` in [`BATCH_TILE`]
    /// tiles: within a tile, element `j`'s four-row weight block streams
    /// once across the tile's windows (block L1-resident over the inner
    /// loop) while the tile's `x`/`h` rows stay hot across all `LH`
    /// blocks. Arithmetic per window is exactly that of
    /// [`Self::step_into`], so results are bit-identical.
    ///
    /// Layout: `x` is `[B][LX]` row-major, `h`/`c` are `[B][LH]` row-major
    /// and are updated in place.
    pub fn step_batch_into(
        &self,
        b: usize,
        h: &mut [Q8_24],
        c: &mut [Q8_24],
        x: &[Q8_24],
        scratch: &mut StepScratch,
    ) {
        let lh = self.w.dims.lh;
        let lx = self.w.dims.lx;
        assert_eq!(x.len(), b * lx);
        assert_eq!(h.len(), b * lh);
        assert_eq!(c.len(), b * lh);
        let g4 = 4 * lh;
        // Pre-activations, `[B][LH][4]` — per-window gate-minor, so the
        // element-wise phase walks each window contiguously. Fully written
        // below before it is read (scratch is not zeroed).
        let pre = scratch.pre(b * g4);
        for tile_start in (0..b).step_by(BATCH_TILE) {
            let tile_end = (tile_start + BATCH_TILE).min(b);
            for j in 0..lh {
                let wxj = &self.w.wx_il[j * 4 * lx..(j + 1) * 4 * lx];
                let whj = &self.w.wh_il[j * 4 * lh..(j + 1) * 4 * lh];
                let bx4 = &self.w.bx_il[j * 4..j * 4 + 4];
                let bh4 = &self.w.bh_il[j * 4..j * 4 + 4];
                for wi in tile_start..tile_end {
                    let base = wi * g4 + j * 4;
                    fused_rows(
                        wxj,
                        whj,
                        bx4,
                        bh4,
                        &x[wi * lx..(wi + 1) * lx],
                        &h[wi * lh..(wi + 1) * lh],
                        &mut pre[base..base + 4],
                    );
                }
            }
        }
        for wi in 0..b {
            gates_apply(
                &self.sigmoid,
                &self.tanh,
                &pre[wi * g4..(wi + 1) * g4],
                &mut h[wi * lh..(wi + 1) * lh],
                &mut c[wi * lh..(wi + 1) * lh],
            );
        }
    }

    /// Row-major reference for [`Self::step_batch_into`] — the
    /// pre-interleave batched kernel (each of the `4·LH` weight rows
    /// streamed once across the whole batch), kept as the
    /// layout-equivalence oracle and bench baseline.
    pub fn step_batch_into_rowmajor(
        &self,
        b: usize,
        h: &mut [Q8_24],
        c: &mut [Q8_24],
        x: &[Q8_24],
        scratch: &mut StepScratch,
    ) {
        let lh = self.w.dims.lh;
        let lx = self.w.dims.lx;
        assert_eq!(x.len(), b * lx);
        assert_eq!(h.len(), b * lh);
        assert_eq!(c.len(), b * lh);
        let g4 = 4 * lh;
        // Pre-activations, `[B][4·LH]` row-major; fully written before the
        // element-wise loop reads them.
        let pre = scratch.pre(b * g4);
        for row in 0..g4 {
            let wx_row = &self.w.wx[row * lx..(row + 1) * lx];
            let wh_row = &self.w.wh[row * lh..(row + 1) * lh];
            let bx = self.w.bx[row];
            let bh = self.w.bh[row];
            for wi in 0..b {
                let xw = &x[wi * lx..(wi + 1) * lx];
                let hw = &h[wi * lh..(wi + 1) * lh];
                let acc_x: i64 =
                    wx_row.iter().zip(xw).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
                let acc_h: i64 =
                    wh_row.iter().zip(hw).map(|(w, v)| w.0 as i64 * v.0 as i64).sum();
                let mx = Q8_24::from_wide(acc_x).add(bx);
                let mh = Q8_24::from_wide(acc_h).add(bh);
                pre[wi * g4 + row] = mx.add(mh);
            }
        }
        for wi in 0..b {
            let pre_w = &pre[wi * g4..(wi + 1) * g4];
            let hw = &mut h[wi * lh..(wi + 1) * lh];
            let cw = &mut c[wi * lh..(wi + 1) * lh];
            for j in 0..lh {
                let i = self.sigmoid.eval_q(pre_w[j]);
                let f = self.sigmoid.eval_q(pre_w[lh + j]);
                let g = self.tanh.eval_q(pre_w[2 * lh + j]);
                let o = self.sigmoid.eval_q(pre_w[3 * lh + j]);
                cw[j] = f.mul(cw[j]).add(i.mul(g));
                hw[j] = o.mul(self.tanh.eval_q(cw[j]));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::LayerDims;
    use crate::util::prop::props;
    use crate::util::rng::Xoshiro256;

    fn mk(lx: usize, lh: usize, seed: u64) -> LayerWeights {
        LayerWeights::random(LayerDims { lx, lh }, &mut Xoshiro256::seeded(seed))
    }

    #[test]
    fn f32_step_shapes() {
        let w = mk(32, 16, 1);
        let s = lstm_step_f32(&w, &LstmState::zeros(16), &vec![0.1; 32]);
        assert_eq!(s.h.len(), 16);
        assert_eq!(s.c.len(), 16);
    }

    #[test]
    fn outputs_bounded_by_gates() {
        // |h| <= 1 always (o in [0,1], tanh(c) in [-1,1]).
        props("h_bounded", 64, |g| {
            let w = mk(8, 8, g.case as u64);
            let x: Vec<f32> = g.vec_f32(8, -3.0, 3.0);
            let mut s = LstmState::zeros(8);
            for _ in 0..5 {
                s = lstm_step_f32(&w, &s, &x);
            }
            assert!(s.h.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        });
    }

    #[test]
    fn zero_everything_is_zero_h() {
        // With zero weights and biases, i=f=o=0.5, g=0 ⇒ c=0, h=0.
        let mut w = mk(4, 4, 3);
        w.wx.iter_mut().for_each(|v| *v = 0.0);
        w.wh.iter_mut().for_each(|v| *v = 0.0);
        w.bx.iter_mut().for_each(|v| *v = 0.0);
        w.bh.iter_mut().for_each(|v| *v = 0.0);
        let s = lstm_step_f32(&w, &LstmState::zeros(4), &[1.0, -1.0, 2.0, 0.5]);
        assert!(s.h.iter().all(|v| v.abs() < 1e-7), "{:?}", s.h);
        assert!(s.c.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn quant_tracks_f32_closely() {
        // Q8.24 + PWL vs f32: error per step is dominated by the PWL
        // approximation (~1.5e-3 on tanh), not quantization.
        props("quant_vs_f32", 24, |g| {
            let w = mk(16, 16, g.case as u64 + 100);
            let cell = QuantLstmCell::new(&w);
            let x: Vec<f32> = g.vec_f32(16, -1.0, 1.0);
            let xq: Vec<Q8_24> = x.iter().map(|&v| Q8_24::from_f32(v)).collect();
            let mut sf = LstmState::zeros(16);
            let mut sq = QuantLstmState::zeros(16);
            for _ in 0..8 {
                sf = lstm_step_f32(&w, &sf, &x);
                sq = cell.step(&sq, &xq);
            }
            for (a, b) in sf.h.iter().zip(sq.h_f32()) {
                assert!((a - b).abs() < 0.02, "f32 {a} vs quant {b}");
            }
        });
    }

    #[test]
    fn quant_step_deterministic() {
        let w = mk(8, 8, 5);
        let cell = QuantLstmCell::new(&w);
        let x: Vec<Q8_24> = (0..8).map(|i| Q8_24::from_f64(i as f64 * 0.1 - 0.4)).collect();
        let a = cell.step(&QuantLstmState::zeros(8), &x);
        let b = cell.step(&QuantLstmState::zeros(8), &x);
        assert_eq!(a.h, b.h);
        assert_eq!(a.c, b.c);
    }

    #[test]
    fn step_into_bit_identical_to_step() {
        // The scratch path must be the same arithmetic, not merely close.
        props("step_into_exact", 48, |g| {
            let lx = 1 + g.usize_in(0, 16);
            let lh = 1 + g.usize_in(0, 16);
            let w = mk(lx, lh, g.case as u64 + 900);
            let cell = QuantLstmCell::new(&w);
            let mut state = QuantLstmState::zeros(lh);
            let mut scratch = StepScratch::new();
            for step_i in 0..4 {
                let x: Vec<Q8_24> =
                    (0..lx).map(|_| Q8_24::from_f64(g.f64_in(-2.0, 2.0))).collect();
                let want = cell.step(&state, &x);
                cell.step_into(&mut state, &x, &mut scratch);
                assert_eq!(state.h, want.h, "h diverged at step {step_i}");
                assert_eq!(state.c, want.c, "c diverged at step {step_i}");
            }
        });
    }

    #[test]
    fn step_batch_into_bit_identical_per_window() {
        props("step_batch_exact", 32, |g| {
            let lx = 1 + g.usize_in(0, 12);
            let lh = 1 + g.usize_in(0, 12);
            let b = 1 + g.usize_in(0, 5);
            let w = mk(lx, lh, g.case as u64 + 1700);
            let cell = QuantLstmCell::new(&w);
            // Per-window golden states driven by repeated single steps.
            let mut golden: Vec<QuantLstmState> =
                (0..b).map(|_| QuantLstmState::zeros(lh)).collect();
            let mut h = vec![Q8_24::ZERO; b * lh];
            let mut c = vec![Q8_24::ZERO; b * lh];
            let mut scratch = StepScratch::new();
            for _ in 0..3 {
                let xs: Vec<Vec<Q8_24>> = (0..b)
                    .map(|_| (0..lx).map(|_| Q8_24::from_f64(g.f64_in(-2.0, 2.0))).collect())
                    .collect();
                let flat: Vec<Q8_24> = xs.iter().flatten().copied().collect();
                cell.step_batch_into(b, &mut h, &mut c, &flat, &mut scratch);
                for (wi, gs) in golden.iter_mut().enumerate() {
                    *gs = cell.step(gs, &xs[wi]);
                    assert_eq!(&h[wi * lh..(wi + 1) * lh], &gs.h[..], "window {wi} h");
                    assert_eq!(&c[wi * lh..(wi + 1) * lh], &gs.c[..], "window {wi} c");
                }
            }
        });
    }

    #[test]
    fn scratch_reuse_across_layer_widths() {
        // One scratch serves layers of different widths back to back.
        let small = mk(4, 4, 21);
        let big = mk(8, 8, 22);
        let cs = QuantLstmCell::new(&small);
        let cb = QuantLstmCell::new(&big);
        let mut scratch = StepScratch::new();
        let mut ss = QuantLstmState::zeros(4);
        let mut sb = QuantLstmState::zeros(8);
        let xs: Vec<Q8_24> = (0..4).map(|i| Q8_24::from_f64(0.1 * i as f64)).collect();
        let xb: Vec<Q8_24> = (0..8).map(|i| Q8_24::from_f64(0.05 * i as f64)).collect();
        cb.step_into(&mut sb, &xb, &mut scratch);
        cs.step_into(&mut ss, &xs, &mut scratch); // shrink after grow
        assert_eq!(ss.h, cs.step(&QuantLstmState::zeros(4), &xs).h);
    }

    #[test]
    fn interleaved_matches_rowmajor_reference() {
        // The gate-interleaved kernel and the row-major oracle must agree
        // bit-for-bit across random shapes, including lh=1 and lx≠lh.
        props("layout_equiv_step", 48, |g| {
            let lx = 1 + g.usize_in(0, 16);
            let lh = 1 + g.usize_in(0, 16);
            let w = mk(lx, lh, g.case as u64 + 4100);
            let cell = QuantLstmCell::new(&w);
            let mut si = QuantLstmState::zeros(lh);
            let mut sr = QuantLstmState::zeros(lh);
            let mut sc_i = StepScratch::new();
            let mut sc_r = StepScratch::new();
            for step_i in 0..4 {
                let x: Vec<Q8_24> =
                    (0..lx).map(|_| Q8_24::from_f64(g.f64_in(-2.0, 2.0))).collect();
                cell.step_into(&mut si, &x, &mut sc_i);
                cell.step_into_rowmajor(&mut sr, &x, &mut sc_r);
                assert_eq!(si.h, sr.h, "h diverged at step {step_i}");
                assert_eq!(si.c, sr.c, "c diverged at step {step_i}");
            }
        });
    }

    #[test]
    fn batched_interleaved_matches_rowmajor_reference() {
        props("layout_equiv_batch", 32, |g| {
            let lx = 1 + g.usize_in(0, 12);
            let lh = 1 + g.usize_in(0, 12);
            let b = 1 + g.usize_in(0, 11); // crosses the BATCH_TILE=8 boundary
            let w = mk(lx, lh, g.case as u64 + 5200);
            let cell = QuantLstmCell::new(&w);
            let mut hi = vec![Q8_24::ZERO; b * lh];
            let mut ci = vec![Q8_24::ZERO; b * lh];
            let mut hr = hi.clone();
            let mut cr = ci.clone();
            let mut sc_i = StepScratch::new();
            let mut sc_r = StepScratch::new();
            for _ in 0..3 {
                let flat: Vec<Q8_24> =
                    (0..b * lx).map(|_| Q8_24::from_f64(g.f64_in(-2.0, 2.0))).collect();
                cell.step_batch_into(b, &mut hi, &mut ci, &flat, &mut sc_i);
                cell.step_batch_into_rowmajor(b, &mut hr, &mut cr, &flat, &mut sc_r);
                assert_eq!(hi, hr);
                assert_eq!(ci, cr);
            }
        });
    }

    #[test]
    fn shared_scratch_across_kernel_layouts() {
        // One scratch alternates between the interleaved and row-major
        // kernels (whose pre-activation layouts differ) without zeroing in
        // between; write-before-read means stale contents never leak.
        let w = mk(8, 8, 31);
        let cell = QuantLstmCell::new(&w);
        let x: Vec<Q8_24> = (0..8).map(|i| Q8_24::from_f64(0.07 * i as f64 - 0.2)).collect();
        let mut shared = StepScratch::new();
        let mut sa = QuantLstmState::zeros(8);
        cell.step_into(&mut sa, &x, &mut shared);
        let mut sb = QuantLstmState::zeros(8);
        cell.step_into_rowmajor(&mut sb, &x, &mut shared);
        assert_eq!(sa.h, sb.h);
        assert_eq!(sa.c, sb.c);
        // And back again, against a fresh-scratch run.
        let mut sc = sa.clone();
        cell.step_into(&mut sc, &x, &mut shared);
        let mut sd = sa.clone();
        cell.step_into(&mut sd, &x, &mut StepScratch::new());
        assert_eq!(sc.h, sd.h);
        assert_eq!(sc.c, sd.c);
    }

    #[test]
    fn thread_arena_is_reentrant_safe() {
        // step() borrows the thread arena; calling it from inside a
        // with_thread_arena scope must not panic (falls back to a fresh
        // temporary arena).
        let w = mk(4, 4, 33);
        let cell = QuantLstmCell::new(&w);
        let x: Vec<Q8_24> = (0..4).map(|i| Q8_24::from_f64(0.1 * i as f64)).collect();
        let outer = cell.step(&QuantLstmState::zeros(4), &x);
        let inner = with_thread_arena(|_| cell.step(&QuantLstmState::zeros(4), &x));
        assert_eq!(outer.h, inner.h);
        assert_eq!(outer.c, inner.c);
    }

    #[test]
    fn state_reset_rezeros_and_resizes() {
        let mut s = QuantLstmState::zeros(4);
        s.h[1] = Q8_24::ONE;
        s.c[2] = Q8_24::ONE;
        s.reset(6);
        assert_eq!(s.h, vec![Q8_24::ZERO; 6]);
        assert_eq!(s.c, vec![Q8_24::ZERO; 6]);
        s.reset(2);
        assert_eq!(s.h.len(), 2);
    }

    #[test]
    fn quant_h_bounded_by_one() {
        props("quant_h_bound", 16, |g| {
            let w = mk(8, 8, g.case as u64 + 300);
            let cell = QuantLstmCell::new(&w);
            let x: Vec<Q8_24> =
                (0..8).map(|_| Q8_24::from_f64(g.f64_in(-5.0, 5.0))).collect();
            let mut s = QuantLstmState::zeros(8);
            for _ in 0..10 {
                s = cell.step(&s, &x);
            }
            for h in &s.h {
                assert!(h.to_f64().abs() <= 1.0 + 1e-6);
            }
        });
    }
}
