//! Stacked LSTM-Autoencoder forward pass and reconstruction scoring.
//!
//! The AE streams a `[T, F]` sequence through `depth` LSTM layers (half
//! encoder, half decoder — see [`super::topology`]); the last layer's
//! hidden sequence *is* the reconstruction (its hidden width equals the
//! input feature width). Anomaly score = per-window mean squared
//! reconstruction error, the standard LSTM-AE criterion (§2).

use anyhow::Result;

use super::lstm::{lstm_step_f32, LstmState, QuantLstmCell};
use super::topology::Topology;
use super::weights::ModelWeights;

/// An LSTM autoencoder with both f32 and quantized (Q8.24 + PWL) forward
/// paths over the same weights.
pub struct LstmAutoencoder {
    pub topo: Topology,
    pub weights: ModelWeights,
    quant_cells: Vec<QuantLstmCell>,
}

impl LstmAutoencoder {
    pub fn new(topo: Topology, weights: ModelWeights) -> Result<LstmAutoencoder> {
        weights.validate(&topo)?;
        let quant_cells = weights.layers.iter().map(QuantLstmCell::new).collect();
        Ok(LstmAutoencoder { topo, weights, quant_cells })
    }

    /// Convenience: deterministic random weights (simulator-only runs).
    pub fn random(topo: Topology, seed: u64) -> LstmAutoencoder {
        let weights = ModelWeights::random(&topo, seed);
        Self::new(topo, weights).expect("random weights match topology")
    }

    /// f32 forward. `x` is row-major `[T][F]`; returns the reconstruction
    /// with the same shape. This is the semantics the AOT-lowered JAX
    /// artifact computes (and the CPU baseline measures).
    pub fn forward_f32(&self, x: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut seq: Vec<Vec<f32>> = x.to_vec();
        for w in &self.weights.layers {
            let mut state = LstmState::zeros(w.dims.lh);
            let mut out = Vec::with_capacity(seq.len());
            for xt in &seq {
                state = lstm_step_f32(w, &state, xt);
                out.push(state.h.clone());
            }
            seq = out;
        }
        seq
    }

    /// Quantized forward — bit-accurate to the FPGA datapath. Input is
    /// quantized onto the Q8.24 grid at the DataReader boundary, exactly
    /// like the accelerator's DMA path. Runs on the engine's zero-alloc
    /// scratch path ([`crate::engine::forward_in_place`]); per-element
    /// arithmetic and ordering are unchanged from the original
    /// layer-at-a-time recurrence, so outputs are bit-identical to it.
    pub fn forward_quant(&self, x: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut seq = crate::engine::quantize_window(x);
        crate::engine::forward_in_place(&self.quant_cells, &mut seq);
        crate::engine::dequantize_window(seq)
    }

    /// The per-layer quantized cells (Q8.24 weights + shared PWL tables),
    /// in layer order — what the execution engines run on.
    pub fn quant_cells(&self) -> &[QuantLstmCell] {
        &self.quant_cells
    }

    /// Mean squared reconstruction error over the window — the anomaly
    /// score. `recon` must be shaped like `x`.
    pub fn mse(x: &[Vec<f32>], recon: &[Vec<f32>]) -> f64 {
        assert_eq!(x.len(), recon.len());
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for (a, b) in x.iter().zip(recon) {
            assert_eq!(a.len(), b.len());
            for (&u, &v) in a.iter().zip(b) {
                let d = (u - v) as f64;
                sum += d * d;
                n += 1;
            }
        }
        sum / n.max(1) as f64
    }

    /// Anomaly score of a window through the f32 path.
    pub fn score_f32(&self, x: &[Vec<f32>]) -> f64 {
        Self::mse(x, &self.forward_f32(x))
    }

    /// Anomaly score through the quantized (FPGA) path.
    pub fn score_quant(&self, x: &[Vec<f32>]) -> f64 {
        Self::mse(x, &self.forward_quant(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::rng::Xoshiro256;

    fn window(t: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..t).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn forward_shapes_all_paper_models() {
        for topo in Topology::paper_models() {
            let f = topo.features;
            let ae = LstmAutoencoder::random(topo, 1);
            let x = window(4, f, 2);
            let y = ae.forward_f32(&x);
            assert_eq!(y.len(), 4);
            assert_eq!(y[0].len(), f);
            let yq = ae.forward_quant(&x);
            assert_eq!(yq.len(), 4);
            assert_eq!(yq[0].len(), f);
        }
    }

    #[test]
    fn quant_path_tracks_f32_path() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 3);
        let x = window(8, 32, 4);
        let yf = ae.forward_f32(&x);
        let yq = ae.forward_quant(&x);
        let mut max_d = 0.0f32;
        for (a, b) in yf.iter().zip(&yq) {
            for (&u, &v) in a.iter().zip(b) {
                max_d = max_d.max((u - v).abs());
            }
        }
        // PWL tanh error compounds across 2 layers and 8 steps.
        assert!(max_d < 0.05, "max |f32 - quant| = {max_d}");
    }

    #[test]
    fn mse_zero_iff_identical() {
        let x = window(3, 8, 5);
        assert_eq!(LstmAutoencoder::mse(&x, &x), 0.0);
        let mut y = x.clone();
        y[1][2] += 0.5;
        assert!(LstmAutoencoder::mse(&x, &y) > 0.0);
    }

    #[test]
    fn longer_window_is_streaming_prefix_consistent() {
        // Streaming property of stacked LSTMs: the first t outputs depend
        // only on the first t inputs.
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 6);
        let x = window(10, 32, 7);
        let full = ae.forward_f32(&x);
        let prefix = ae.forward_f32(&x[..4]);
        for t in 0..4 {
            for (a, b) in full[t].iter().zip(&prefix[t]) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn rejects_mismatched_weights() {
        let t2 = Topology::from_name("F32-D2").unwrap();
        let t6 = Topology::from_name("F32-D6").unwrap();
        let w = ModelWeights::random(&t2, 1);
        assert!(LstmAutoencoder::new(t6, w).is_err());
    }
}
