//! LSTM-Autoencoder model definitions and golden (bit-accurate) software
//! implementations.
//!
//! - [`topology`] — `LSTM-AE-F{X}-D{Y}` naming → per-layer dimensions
//!   (paper §4.1).
//! - [`weights`] — weight container + binary loader for the
//!   `artifacts/weights_<model>.bin` files written by `python/compile/train.py`,
//!   and a deterministic random initializer for artifact-free tests.
//! - [`lstm`] — a single LSTM cell in f32 and in the Q8.24 + PWL datapath
//!   the FPGA uses.
//! - [`autoencoder`] — the stacked encoder/decoder forward pass and
//!   reconstruction-error scoring.

pub mod topology;
pub mod weights;
pub mod lstm;
pub mod autoencoder;

pub use autoencoder::LstmAutoencoder;
pub use topology::Topology;
pub use weights::{LayerWeights, ModelWeights};
