//! `LSTM-AE-F{X}-D{Y}` topology derivation (paper §4.1).
//!
//! The naming indicates an input feature size `X` and `Y` total LSTM
//! layers — half encoder, half decoder, feature sizes halving down to the
//! bottleneck and doubling back up symmetrically. E.g.:
//!
//! - `LSTM-AE-F32-D2`: 32 → 16 → 32 (2 layers)
//! - `LSTM-AE-F32-D6`: 32 → 16 → 8 → 4 → 8 → 16 → 32 (6 layers)
//!
//! Layer *i* consumes `LX_i` features and produces `LH_i` features; the
//! last layer's hidden size equals the input feature size, so the decoder
//! output *is* the reconstruction (no extra dense layer — matching the
//! paper's feature-size chains).

use anyhow::{bail, Result};

/// One LSTM layer's dimensions (paper notation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerDims {
    /// Input feature dimension `LX_i`.
    pub lx: usize,
    /// Hidden state dimension `LH_i`.
    pub lh: usize,
}

/// An LSTM-AE topology: input width + the per-layer dimension chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Canonical name, e.g. `LSTM-AE-F32-D2`.
    pub name: String,
    /// Input feature size `X`.
    pub features: usize,
    /// Total LSTM layer count `Y`.
    pub depth: usize,
    /// Per-layer dims, `depth` entries.
    pub layers: Vec<LayerDims>,
}

impl Topology {
    /// Build the four paper models or any `F{X}-D{Y}` combination with
    /// `X` divisible by 2^(Y/2) and `Y` even.
    pub fn new(features: usize, depth: usize) -> Result<Topology> {
        if depth == 0 || depth % 2 != 0 {
            bail!("depth must be even and positive, got {depth}");
        }
        let half = depth / 2;
        if features >> half == 0 {
            bail!("features {features} too small for depth {depth}");
        }
        if features % (1 << half) != 0 {
            bail!("features {features} not divisible by 2^{half}");
        }
        // Feature chain: X, X/2, ..., X/2^half, ..., X/2, X
        let mut chain = Vec::with_capacity(depth + 1);
        for i in 0..=half {
            chain.push(features >> i);
        }
        for i in (0..half).rev() {
            chain.push(features >> i);
        }
        let layers =
            (0..depth).map(|i| LayerDims { lx: chain[i], lh: chain[i + 1] }).collect();
        Ok(Topology {
            name: format!("LSTM-AE-F{features}-D{depth}"),
            features,
            depth,
            layers,
        })
    }

    /// Parse `LSTM-AE-F{X}-D{Y}` (or the short `F{X}-D{Y}`).
    pub fn from_name(name: &str) -> Result<Topology> {
        let short = name.strip_prefix("LSTM-AE-").unwrap_or(name);
        let Some((f_part, d_part)) = short.split_once("-D") else {
            bail!("bad model name {name:?} (want LSTM-AE-F{{X}}-D{{Y}})");
        };
        let Some(f_str) = f_part.strip_prefix('F') else {
            bail!("bad model name {name:?}");
        };
        let features: usize = f_str.parse()?;
        let depth: usize = d_part.parse()?;
        Topology::new(features, depth)
    }

    /// The four models evaluated in the paper (§4.1), in Table 1 order.
    pub fn paper_models() -> Vec<Topology> {
        ["LSTM-AE-F32-D2", "LSTM-AE-F64-D2", "LSTM-AE-F32-D6", "LSTM-AE-F64-D6"]
            .iter()
            .map(|n| Topology::from_name(n).expect("paper models are valid"))
            .collect()
    }

    /// Feature-size chain `X → … → X` (depth+1 entries), for display.
    pub fn chain(&self) -> Vec<usize> {
        let mut c = vec![self.layers[0].lx];
        c.extend(self.layers.iter().map(|l| l.lh));
        c
    }

    /// Total multiply-accumulate operations per timestep:
    /// each layer does `4·LH·(LX + LH)` MACs (two MVMs over the 4 gates).
    pub fn macs_per_timestep(&self) -> u64 {
        self.layers.iter().map(|l| 4 * l.lh as u64 * (l.lx as u64 + l.lh as u64)).sum()
    }

    /// Total weight parameters (incl. the two bias vectors per layer).
    pub fn param_count(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                let lh4 = 4 * l.lh as u64;
                lh4 * l.lx as u64 + lh4 * l.lh as u64 + 2 * lh4
            })
            .sum()
    }

    /// Index of the bottleneck-latency layer `m` under balanced reuse:
    /// the layer with the largest hidden dimension (ties → later layer,
    /// matching the decoder-side output layer that dominates).
    pub fn widest_layer(&self) -> usize {
        let mut m = 0;
        for (i, l) in self.layers.iter().enumerate() {
            if l.lh >= self.layers[m].lh {
                m = i;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_chains_match_section_4_1() {
        let t = Topology::from_name("LSTM-AE-F32-D2").unwrap();
        assert_eq!(t.chain(), vec![32, 16, 32]);
        let t = Topology::from_name("LSTM-AE-F32-D6").unwrap();
        assert_eq!(t.chain(), vec![32, 16, 8, 4, 8, 16, 32]);
        let t = Topology::from_name("LSTM-AE-F64-D2").unwrap();
        assert_eq!(t.chain(), vec![64, 32, 64]);
        let t = Topology::from_name("LSTM-AE-F64-D6").unwrap();
        assert_eq!(t.chain(), vec![64, 32, 16, 8, 16, 32, 64]);
    }

    #[test]
    fn layer_dims_are_consistent() {
        for t in Topology::paper_models() {
            assert_eq!(t.layers.len(), t.depth);
            // Chain continuity: layer i's input is layer i-1's hidden.
            for w in t.layers.windows(2) {
                assert_eq!(w[0].lh, w[1].lx);
            }
            assert_eq!(t.layers[0].lx, t.features);
            assert_eq!(t.layers.last().unwrap().lh, t.features);
        }
    }

    #[test]
    fn parses_short_names() {
        assert_eq!(Topology::from_name("F32-D2").unwrap().name, "LSTM-AE-F32-D2");
    }

    #[test]
    fn rejects_bad_names_and_dims() {
        assert!(Topology::from_name("GRU-F32-D2").is_err());
        assert!(Topology::from_name("LSTM-AE-F32-D3").is_err(), "odd depth");
        assert!(Topology::from_name("LSTM-AE-F4-D8").is_err(), "too deep");
        assert!(Topology::from_name("LSTM-AE-F6-D4").is_err(), "not divisible");
    }

    #[test]
    fn macs_per_timestep_f32d2() {
        // L0: 4*16*(32+16) = 3072; L1: 4*32*(16+32) = 6144.
        let t = Topology::from_name("F32-D2").unwrap();
        assert_eq!(t.macs_per_timestep(), 3072 + 6144);
    }

    #[test]
    fn widest_layer_is_output_layer() {
        for t in Topology::paper_models() {
            assert_eq!(t.widest_layer(), t.depth - 1);
            assert_eq!(t.layers[t.widest_layer()].lh, t.features);
        }
    }

    #[test]
    fn depth_scaling_models_exist() {
        // The depth-scalability figure sweeps D2..D10 at F64.
        for d in [2usize, 4, 6, 8, 10] {
            let t = Topology::new(64, d);
            if d <= 10 && 64 >> (d / 2) > 0 && 64 % (1 << (d / 2)) == 0 {
                assert!(t.is_ok(), "D{d}");
            }
        }
    }
}
