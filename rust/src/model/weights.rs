//! Weight containers and the binary interchange format shared with
//! `python/compile/train.py`.
//!
//! ## `weights_<model>.bin` layout (little-endian)
//!
//! ```text
//! magic   u32 = 0x4C414557  ("LAEW")
//! version u32 = 1
//! n_layers u32
//! per layer:
//!   lx u32, lh u32
//!   wx  f32[4*lh][lx]   input MVM weights,  gate order i, f, g, o
//!   wh  f32[4*lh][lh]   hidden MVM weights, gate order i, f, g, o
//!   bx  f32[4*lh]       input bias  (b_i* in the paper's equations)
//!   bh  f32[4*lh]       hidden bias (b_h*)
//! ```
//!
//! Gate order `i, f, g, o` matches the paper's equation order (and
//! PyTorch's convention), and is asserted on both sides by tests.

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

use super::topology::{LayerDims, Topology};
use crate::fixed::Q8_24;
use crate::util::rng::Xoshiro256;

pub const WEIGHTS_MAGIC: u32 = 0x4C41_4557;
pub const WEIGHTS_VERSION: u32 = 1;

/// One layer's parameters in f32 (training precision).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub dims: LayerDims,
    /// `[4*lh * lx]`, row-major `[gate*lh + j][k]`.
    pub wx: Vec<f32>,
    /// `[4*lh * lh]`.
    pub wh: Vec<f32>,
    pub bx: Vec<f32>,
    pub bh: Vec<f32>,
}

impl LayerWeights {
    /// Deterministic uniform init in ±1/√LH (PyTorch's LSTM default),
    /// for artifact-free tests and simulator-only runs.
    pub fn random(dims: LayerDims, rng: &mut Xoshiro256) -> LayerWeights {
        let bound = 1.0 / (dims.lh as f64).sqrt();
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform(-bound, bound) as f32).collect()
        };
        let lh4 = 4 * dims.lh;
        LayerWeights {
            dims,
            wx: draw(lh4 * dims.lx),
            wh: draw(lh4 * dims.lh),
            bx: draw(lh4),
            bh: draw(lh4),
        }
    }

    /// Quantize all parameters onto the Q8.24 grid (what the FPGA stores
    /// in BRAM) and build the gate-interleaved kernel layout.
    pub fn quantized(&self) -> QuantLayerWeights {
        QuantLayerWeights::from_rows(
            self.dims,
            self.wx.iter().map(|&v| Q8_24::from_f32(v)).collect(),
            self.wh.iter().map(|&v| Q8_24::from_f32(v)).collect(),
            self.bx.iter().map(|&v| Q8_24::from_f32(v)).collect(),
            self.bh.iter().map(|&v| Q8_24::from_f32(v)).collect(),
        )
    }
}

/// One layer's parameters on the Q8.24 grid, stored twice:
///
/// - **Row-major** (`wx`/`wh`/`bx`/`bh`) — the interchange layout,
///   `[gate*lh + j][k]` with gate order i, f, g, o. The reference kernels
///   and the weight-format tests read this form.
/// - **Gate-interleaved** (`wx_il`/`wh_il`/`bx_il`/`bh_il`) — the kernel
///   layout: for each output element `j`, the four gates' weights for the
///   same input `k` sit adjacently (`[j][k][4]`), so one streaming pass
///   over `x`/`h` feeds all four gate dot products of `j` and the
///   autovectorizer gets four contiguous i32 lanes per load. Built once at
///   quantization time; the duplication is ~2x weight BRAM, the same trade
///   the FPGA makes when it banks weights per MVM unit.
#[derive(Clone, Debug)]
pub struct QuantLayerWeights {
    pub dims: LayerDims,
    /// Row-major `[4*lh][lx]` input weights (interchange layout).
    pub wx: Vec<Q8_24>,
    /// Row-major `[4*lh][lh]` hidden weights (interchange layout).
    pub wh: Vec<Q8_24>,
    /// Row-major `[4*lh]` input bias.
    pub bx: Vec<Q8_24>,
    /// Row-major `[4*lh]` hidden bias.
    pub bh: Vec<Q8_24>,
    /// Gate-interleaved `[lh][lx][4]` input weights:
    /// `wx_il[(j*lx + k)*4 + g] == wx[(g*lh + j)*lx + k]`.
    pub wx_il: Vec<Q8_24>,
    /// Gate-interleaved `[lh][lh][4]` hidden weights.
    pub wh_il: Vec<Q8_24>,
    /// Gate-interleaved `[lh][4]` input bias: `bx_il[j*4 + g] == bx[g*lh + j]`.
    pub bx_il: Vec<Q8_24>,
    /// Gate-interleaved `[lh][4]` hidden bias.
    pub bh_il: Vec<Q8_24>,
}

impl QuantLayerWeights {
    /// Build from row-major parameters, deriving the gate-interleaved
    /// mirror arrays. All construction goes through here so the two
    /// layouts can never disagree.
    pub fn from_rows(
        dims: LayerDims,
        wx: Vec<Q8_24>,
        wh: Vec<Q8_24>,
        bx: Vec<Q8_24>,
        bh: Vec<Q8_24>,
    ) -> QuantLayerWeights {
        let (lx, lh) = (dims.lx, dims.lh);
        assert_eq!(wx.len(), 4 * lh * lx);
        assert_eq!(wh.len(), 4 * lh * lh);
        assert_eq!(bx.len(), 4 * lh);
        assert_eq!(bh.len(), 4 * lh);
        let interleave = |rows: &[Q8_24], width: usize| -> Vec<Q8_24> {
            let mut out = vec![Q8_24::ZERO; 4 * lh * width];
            for g in 0..4 {
                for j in 0..lh {
                    let row = g * lh + j;
                    for k in 0..width {
                        out[(j * width + k) * 4 + g] = rows[row * width + k];
                    }
                }
            }
            out
        };
        let wx_il = interleave(&wx, lx);
        let wh_il = interleave(&wh, lh);
        let bx_il = interleave(&bx, 1);
        let bh_il = interleave(&bh, 1);
        QuantLayerWeights { dims, wx, wh, bx, bh, wx_il, wh_il, bx_il, bh_il }
    }
}

/// A full model's weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    pub fn random(topo: &Topology, seed: u64) -> ModelWeights {
        let mut rng = Xoshiro256::seeded(seed);
        ModelWeights {
            layers: topo.layers.iter().map(|&d| LayerWeights::random(d, &mut rng)).collect(),
        }
    }

    /// Load from the binary format written by `python/compile/train.py`.
    pub fn load(path: &Path) -> Result<ModelWeights> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf).with_context(|| format!("parse {path:?}"))
    }

    pub fn from_bytes(buf: &[u8]) -> Result<ModelWeights> {
        let mut cur = Cursor { buf, pos: 0 };
        let magic = cur.u32()?;
        if magic != WEIGHTS_MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let version = cur.u32()?;
        if version != WEIGHTS_VERSION {
            bail!("unsupported weights version {version}");
        }
        let n_layers = cur.u32()? as usize;
        if n_layers == 0 || n_layers > 64 {
            bail!("implausible layer count {n_layers}");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let lx = cur.u32()? as usize;
            let lh = cur.u32()? as usize;
            if lx == 0 || lh == 0 || lx > 65536 || lh > 65536 {
                bail!("implausible dims lx={lx} lh={lh}");
            }
            let lh4 = 4 * lh;
            layers.push(LayerWeights {
                dims: LayerDims { lx, lh },
                wx: cur.f32s(lh4 * lx)?,
                wh: cur.f32s(lh4 * lh)?,
                bx: cur.f32s(lh4)?,
                bh: cur.f32s(lh4)?,
            });
        }
        if cur.pos != buf.len() {
            bail!("trailing bytes: {} of {}", buf.len() - cur.pos, buf.len());
        }
        Ok(ModelWeights { layers })
    }

    /// Serialize to the interchange format (used by tests to round-trip and
    /// by `examples/` to snapshot randomly-initialized models).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push_u32 = |out: &mut Vec<u8>, v: u32| out.extend_from_slice(&v.to_le_bytes());
        let push_f32s = |out: &mut Vec<u8>, vs: &[f32]| {
            vs.iter().for_each(|v| out.extend_from_slice(&v.to_le_bytes()))
        };
        push_u32(&mut out, WEIGHTS_MAGIC);
        push_u32(&mut out, WEIGHTS_VERSION);
        push_u32(&mut out, self.layers.len() as u32);
        for l in &self.layers {
            push_u32(&mut out, l.dims.lx as u32);
            push_u32(&mut out, l.dims.lh as u32);
            push_f32s(&mut out, &l.wx);
            push_f32s(&mut out, &l.wh);
            push_f32s(&mut out, &l.bx);
            push_f32s(&mut out, &l.bh);
        }
        out
    }

    /// Check the weights match a topology.
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        if self.layers.len() != topo.depth {
            bail!("weights have {} layers, topology {}", self.layers.len(), topo.depth);
        }
        for (i, (w, d)) in self.layers.iter().zip(&topo.layers).enumerate() {
            if w.dims != *d {
                bail!("layer {i}: weights {:?} != topology {:?}", w.dims, d);
            }
        }
        Ok(())
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.buf.len() {
            bail!("truncated at byte {}", self.pos);
        }
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = n * 4;
        if self.pos + bytes > self.buf.len() {
            bail!("truncated f32 block at byte {} (want {n} values)", self.pos);
        }
        let out = self.buf[self.pos..self.pos + bytes]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += bytes;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let w = ModelWeights::random(&topo, 7);
        let bytes = w.to_bytes();
        let back = ModelWeights::from_bytes(&bytes).unwrap();
        back.validate(&topo).unwrap();
        for (a, b) in w.layers.iter().zip(&back.layers) {
            assert_eq!(a.wx, b.wx);
            assert_eq!(a.wh, b.wh);
            assert_eq!(a.bx, b.bx);
            assert_eq!(a.bh, b.bh);
        }
    }

    #[test]
    fn rejects_corrupt() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let mut bytes = ModelWeights::random(&topo, 7).to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(ModelWeights::from_bytes(&bad).is_err());
        // Truncation.
        bytes.truncate(bytes.len() - 3);
        assert!(ModelWeights::from_bytes(&bytes).is_err());
        // Trailing garbage.
        let mut long = ModelWeights::random(&topo, 7).to_bytes();
        long.push(0);
        assert!(ModelWeights::from_bytes(&long).is_err());
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let a = ModelWeights::random(&topo, 42);
        let b = ModelWeights::random(&topo, 42);
        assert_eq!(a.layers[3].wx, b.layers[3].wx);
        let bound = 1.0 / (topo.layers[0].lh as f32).sqrt();
        assert!(a.layers[0].wx.iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn validate_catches_mismatch() {
        let t2 = Topology::from_name("F32-D2").unwrap();
        let t6 = Topology::from_name("F32-D6").unwrap();
        let w = ModelWeights::random(&t2, 1);
        assert!(w.validate(&t6).is_err());
        assert!(w.validate(&t2).is_ok());
    }

    #[test]
    fn interleaved_layout_mirrors_row_major() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let w = ModelWeights::random(&topo, 11);
        for layer in &w.layers {
            let q = layer.quantized();
            let (lx, lh) = (q.dims.lx, q.dims.lh);
            for g in 0..4 {
                for j in 0..lh {
                    let row = g * lh + j;
                    for k in 0..lx {
                        assert_eq!(q.wx_il[(j * lx + k) * 4 + g], q.wx[row * lx + k]);
                    }
                    for k in 0..lh {
                        assert_eq!(q.wh_il[(j * lh + k) * 4 + g], q.wh[row * lh + k]);
                    }
                    assert_eq!(q.bx_il[j * 4 + g], q.bx[row]);
                    assert_eq!(q.bh_il[j * 4 + g], q.bh[row]);
                }
            }
        }
    }

    #[test]
    fn quantized_weights_on_grid() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let w = ModelWeights::random(&topo, 9);
        let q = w.layers[0].quantized();
        for (f, qv) in w.layers[0].wx.iter().zip(&q.wx) {
            assert!((qv.to_f64() - *f as f64).abs() <= 0.5 / crate::fixed::SCALE);
        }
    }
}
