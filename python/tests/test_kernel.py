# pytest: Pallas kernel vs pure-jnp ref — the CORE correctness signal.
# Hypothesis sweeps shapes/seeds; assert_allclose against ref.py.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lstm_cell import (
    lstm_cell_pallas,
    lstm_cell_pallas_tiled,
    vmem_bytes,
)
from compile.kernels.ref import lstm_cell_ref, lstm_layer_ref
from compile.model import init_params
from compile.topology import Topology


def make_params(lx: int, lh: int, seed: int):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    bound = 1.0 / np.sqrt(lh)
    u = lambda k, shape: jax.random.uniform(k, shape, jnp.float32, -bound, bound)
    params = {
        "wx": u(k1, (4 * lh, lx)),
        "wh": u(k2, (4 * lh, lh)),
        "bx": u(k3, (4 * lh,)),
        "bh": u(k4, (4 * lh,)),
    }
    h = u(k5, (lh,))
    c = u(k6, (lh,))
    x = jax.random.uniform(k7, (lx,), jnp.float32, -1.0, 1.0)
    return params, h, c, x


@settings(max_examples=25, deadline=None)
@given(
    lx=st.sampled_from([4, 8, 16, 32, 64]),
    lh=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_across_shapes(lx, lh, seed):
    params, h, c, x = make_params(lx, lh, seed)
    h_ref, c_ref = lstm_cell_ref(params, h, c, x)
    h_pal, c_pal = lstm_cell_pallas(params, h, c, x)
    np.testing.assert_allclose(h_pal, h_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c_pal, c_ref, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    lh=st.sampled_from([8, 16, 32]),
    reuse=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_kernel_matches_ref(lh, reuse, seed):
    # reuse divides 4·LH for all sampled combinations.
    params, h, c, x = make_params(lh, lh, seed)
    h_ref, c_ref = lstm_cell_ref(params, h, c, x)
    h_t, c_t = lstm_cell_pallas_tiled(params, h, c, x, reuse=reuse)
    np.testing.assert_allclose(h_t, h_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c_t, c_ref, rtol=1e-6, atol=1e-6)


def test_tiled_rejects_nondivisible_reuse():
    params, h, c, x = make_params(8, 8, 0)
    with pytest.raises(ValueError):
        lstm_cell_pallas_tiled(params, h, c, x, reuse=3)


def test_kernel_inside_scan_matches_loop_oracle():
    # The kernel must compose with lax.scan (how the artifact uses it).
    topo = Topology.from_name("F32-D2")
    params = init_params(topo, jax.random.PRNGKey(3))[0]
    xs = jax.random.uniform(jax.random.PRNGKey(4), (6, 32), jnp.float32, -1.0, 1.0)

    def step(carry, x):
        h, c = carry
        h2, c2 = lstm_cell_pallas(params, h, c, x)
        return (h2, c2), h2

    h0 = jnp.zeros((16,), jnp.float32)
    c0 = jnp.zeros((16,), jnp.float32)
    _, ys = jax.lax.scan(step, (h0, c0), xs)
    np.testing.assert_allclose(ys, lstm_layer_ref(params, xs), rtol=1e-6, atol=1e-6)


def test_state_bounds_hold():
    # |h| ≤ 1 structurally (o ∈ [0,1], tanh ∈ [−1,1]).
    params, h, c, x = make_params(16, 16, 7)
    for _ in range(20):
        h, c = lstm_cell_pallas(params, h, c, 3.0 * x)
    assert np.all(np.abs(np.asarray(h)) <= 1.0 + 1e-6)


def test_vmem_estimate_monotone_in_reuse():
    full = vmem_bytes(64, 64, reuse=1)
    tiled = vmem_bytes(64, 64, reuse=8)
    assert tiled < full
    # F64 bottleneck layer tile fits comfortably in a 16 MiB VMEM budget.
    assert full < 16 * 2**20
