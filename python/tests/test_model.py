# L2 model tests: scan-vs-loop equivalence, pallas-vs-jnp paths, shapes,
# streaming-prefix property, and training convergence on the synthetic
# telemetry.

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as model_lib
from compile.datagen import Telemetry
from compile.kernels.ref import lstm_ae_ref
from compile.topology import PAPER_MODELS, Topology


def window(t, f, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (t, f), jnp.float32, -1.0, 1.0)


def test_topology_chains_match_paper():
    assert Topology.from_name("LSTM-AE-F32-D2").chain() == [32, 16, 32]
    assert Topology.from_name("F32-D6").chain() == [32, 16, 8, 4, 8, 16, 32]
    assert Topology.from_name("F64-D6").chain() == [64, 32, 16, 8, 16, 32, 64]


def test_forward_shapes_all_paper_models():
    for name in PAPER_MODELS:
        topo = Topology.from_name(name)
        params = model_lib.init_params(topo, jax.random.PRNGKey(0))
        xs = window(4, topo.features, 1)
        out = model_lib.forward(params, xs, use_pallas=False)
        assert out.shape == xs.shape


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([1, 2, 5, 9]))
def test_scan_forward_matches_loop_oracle(seed, t):
    topo = Topology.from_name("F32-D2")
    params = model_lib.init_params(topo, jax.random.PRNGKey(seed))
    xs = window(t, 32, seed)
    got = model_lib.forward(params, xs, use_pallas=False)
    want = lstm_ae_ref(params, xs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pallas_path_matches_jnp_path():
    topo = Topology.from_name("F32-D6")
    params = model_lib.init_params(topo, jax.random.PRNGKey(2))
    xs = window(6, 32, 3)
    a = model_lib.forward(params, xs, use_pallas=True)
    b = model_lib.forward(params, xs, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_streaming_prefix_property():
    topo = Topology.from_name("F32-D2")
    params = model_lib.init_params(topo, jax.random.PRNGKey(5))
    xs = window(10, 32, 6)
    full = model_lib.forward(params, xs, use_pallas=False)
    prefix = model_lib.forward(params, xs[:4], use_pallas=False)
    np.testing.assert_allclose(full[:4], prefix, rtol=1e-5, atol=1e-6)


def test_batched_forward_matches_per_window():
    topo = Topology.from_name("F32-D2")
    params = model_lib.init_params(topo, jax.random.PRNGKey(7))
    xb = jnp.stack([window(4, 32, s) for s in range(3)])
    batched = model_lib.forward_batched(params, xb, use_pallas=False)
    for i in range(3):
        single = model_lib.forward(params, xb[i], use_pallas=False)
        np.testing.assert_allclose(batched[i], single, rtol=1e-6, atol=1e-6)


def test_telemetry_windows_shape_and_range():
    gen = Telemetry(32, seed=1)
    xb = gen.windows(8, 16)
    assert xb.shape == (8, 16, 32)
    assert np.all(np.abs(xb) < 1.5)


def test_training_reduces_loss_quickly():
    # A cheap convergence check on the smallest model: loss should drop
    # well below the variance of the signal within a few dozen steps.
    from compile.train import train_model

    topo = Topology.from_name("F32-D2")
    losses = []
    params, final = train_model(
        topo, steps=60, batch=16, window=8, log=lambda s: losses.append(s)
    )
    assert final < 0.05, f"final loss {final}"
    xs = jnp.asarray(Telemetry(32, seed=99).windows(1, 8)[0])
    recon = model_lib.forward(params, xs, use_pallas=False)
    assert float(jnp.mean((recon - xs) ** 2)) < 0.1
