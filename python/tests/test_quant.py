# Quantization grid + PWL activation properties — mirrors the invariants
# asserted on the Rust side (rust/src/fixed, rust/src/activations) so the
# two implementations stay in lock-step.

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.quant import (
    PWL_HI,
    PWL_LO,
    SCALE,
    SEGMENTS,
    lstm_cell_quant,
    pwl_sigmoid,
    pwl_tanh,
    quantize,
)
from compile.kernels.ref import lstm_cell_ref
from tests.test_kernel import make_params


@settings(max_examples=50, deadline=None)
@given(v=st.floats(-120.0, 120.0, allow_nan=False))
def test_quantize_error_bounded(v):
    q = float(quantize(v))
    assert abs(q - v) <= 0.5 / SCALE + 1e-15


def test_quantize_idempotent_and_saturating():
    xs = jnp.asarray([-1e9, -128.5, -1.0, 0.0, 0.3, 127.9, 1e9])
    q1 = quantize(xs)
    np.testing.assert_array_equal(np.asarray(quantize(q1)), np.asarray(q1))
    assert float(q1[0]) == -(2.0**31) / SCALE
    assert float(q1[-1]) == (2.0**31 - 1) / SCALE


def test_grid_spec_matches_rust():
    # The contract with rust/src/activations: [-8, 8], 128 segments.
    assert (PWL_LO, PWL_HI, SEGMENTS) == (-8.0, 8.0, 128)


@settings(max_examples=40, deadline=None)
@given(x=st.floats(-12.0, 12.0, allow_nan=False))
def test_pwl_error_bounds(x):
    # Same bounds the Rust tests assert: sigmoid < 4e-4, tanh < 2e-3
    # (vs the saturated reference outside [-8, 8]).
    sig_ref = 0.0 if x <= PWL_LO else (1.0 if x >= PWL_HI else 1.0 / (1.0 + np.exp(-x)))
    tanh_ref = -1.0 if x <= PWL_LO else (1.0 if x >= PWL_HI else np.tanh(x))
    assert abs(float(pwl_sigmoid(x)) - sig_ref) < 4e-4
    assert abs(float(pwl_tanh(x)) - tanh_ref) < 2e-3


def test_pwl_monotone():
    xs = np.linspace(-10, 10, 4001)
    for fn in (pwl_sigmoid, pwl_tanh):
        ys = np.asarray(fn(jnp.asarray(xs)))
        assert np.all(np.diff(ys) >= -1e-12)


def test_pwl_tanh_odd_symmetry():
    xs = np.linspace(0, 8, 257)
    pos = np.asarray(pwl_tanh(jnp.asarray(xs)))
    neg = np.asarray(pwl_tanh(jnp.asarray(-xs)))
    np.testing.assert_allclose(pos + neg, 0.0, atol=4.0 / SCALE)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quant_cell_tracks_f32_cell(seed):
    # Same tolerance the Rust golden-model test uses (PWL dominates).
    params, h, c, x = make_params(16, 16, seed)
    hq, cq = h, c
    hf, cf = h, c
    for _ in range(8):
        hf, cf = lstm_cell_ref(params, hf, cf, x)
        hq, cq = lstm_cell_quant(params, hq, cq, x)
    np.testing.assert_allclose(np.asarray(hq), np.asarray(hf), atol=0.02)


def test_quant_cell_outputs_on_grid():
    params, h, c, x = make_params(8, 8, 3)
    hq, _cq = lstm_cell_quant(params, h, c, x)
    raw = np.asarray(hq, dtype=np.float64) * SCALE
    np.testing.assert_allclose(raw, np.round(raw), atol=1e-6)
