# AOT path tests: HLO text structure, weights-binary round-trip, and
# manifest consistency — the contract with the Rust runtime.

import json
from pathlib import Path

import jax
import numpy as np

from compile import aot, model as model_lib, train as train_lib
from compile.topology import Topology


def tiny_params():
    topo = Topology.from_name("F8-D2")
    return topo, model_lib.init_params(topo, jax.random.PRNGKey(0))


def test_lowered_hlo_text_structure():
    topo, params = tiny_params()
    text = aot.lower_model(params, t=3, features=topo.features)
    assert "ENTRY" in text
    assert "f32[3,8]" in text, "input parameter shape embedded"
    # Weights are baked in as constants: exactly one runtime parameter.
    entry = [l for l in text.splitlines() if "ENTRY" in l][0]
    assert entry.count("parameter") <= 1 or "param" in entry
    # return_tuple=True → tuple root.
    assert "tuple" in text


def test_hlo_is_deterministic():
    topo, params = tiny_params()
    a = aot.lower_model(params, t=2, features=topo.features)
    b = aot.lower_model(params, t=2, features=topo.features)
    assert a == b


def test_weights_bin_roundtrip(tmp_path: Path):
    topo, params = tiny_params()
    f = tmp_path / "w.bin"
    train_lib.write_weights_bin(f, params)
    back = train_lib.read_weights_bin(f)
    assert len(back) == len(params)
    for a, b in zip(params, back):
        for k in ("wx", "wh", "bx", "bh"):
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_weights_bin_header(tmp_path: Path):
    topo, params = tiny_params()
    f = tmp_path / "w.bin"
    train_lib.write_weights_bin(f, params)
    buf = f.read_bytes()
    import struct

    magic, version, n_layers = struct.unpack_from("<III", buf, 0)
    assert magic == 0x4C414557  # "LAEW" — matches rust WEIGHTS_MAGIC
    assert version == 1
    assert n_layers == topo.depth
    lx, lh = struct.unpack_from("<II", buf, 12)
    assert (lx, lh) == (topo.layers[0].lx, topo.layers[0].lh)


def test_build_all_manifest_consistency(tmp_path: Path):
    # End-to-end build of one tiny model with 2 sequence lengths.
    aot.build_all(
        tmp_path,
        steps=5,
        timesteps=(1, 2),
        models=("LSTM-AE-F8-D2",),
        log=lambda *_: None,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["quant"] == {"word": 32, "frac_bits": 24}
    (entry,) = manifest["models"]
    assert entry["name"] == "LSTM-AE-F8-D2"
    assert entry["layers"] == [8, 4, 8]
    for t in ("1", "2"):
        f = tmp_path / entry["hlo"][t]
        assert f.exists() and f.stat().st_size > 100
    assert (tmp_path / entry["weights"]).exists()
    assert entry["train_loss"] >= 0.0
