"""Train the four paper LSTM-AE models on synthetic benign telemetry.

Standard LSTM-AE recipe (§2): minimize reconstruction MSE on benign
windows only; at deployment anomalous inputs reconstruct poorly and score
above threshold.

Training uses the pure-jnp cell (identical math to the Pallas kernel —
pytest asserts this — but faster to differentiate under interpret mode).
Adam is implemented inline: the offline image has no optax.

Also writes ``weights_<model>.bin`` in the Rust interchange format
(magic "LAEW", little-endian; see rust/src/model/weights.rs).
"""

from __future__ import annotations

import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_lib
from .datagen import Telemetry
from .topology import Topology

WEIGHTS_MAGIC = 0x4C414557
WEIGHTS_VERSION = 1


def telemetry_for(features: int) -> Telemetry:
    """The canonical training telemetry family for a feature width."""
    return Telemetry(features, seed=1234 + features)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train_model(
    topo: Topology,
    *,
    seed: int = 0,
    steps: int = 240,
    batch: int = 32,
    window: int = 16,
    log=print,
):
    """Train one model; returns (params, final_loss)."""
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(topo, key)
    # One telemetry family per feature width (seed depends on features
    # only) — its spec is exported to artifacts/ so the Rust serving side
    # generates the exact family the model learned.
    data = telemetry_for(topo.features)

    def loss_fn(p, xb):
        recon = jax.vmap(lambda w: model_lib.forward(p, w, use_pallas=False))(xb)
        return jnp.mean((recon - xb) ** 2)

    value_grad = jax.jit(jax.value_and_grad(loss_fn))
    opt = adam_init(params)
    loss = float("nan")
    for step in range(steps):
        xb = jnp.asarray(data.windows(batch, window))
        loss, grads = value_grad(params, xb)
        params, opt = adam_update(params, grads, opt)
        if step % 60 == 0 or step == steps - 1:
            log(f"  [{topo.name}] step {step:4d} loss {float(loss):.6f}")
    return params, float(loss)


def write_weights_bin(path: Path, params) -> None:
    """Serialize to the Rust interchange format (LAEW v1)."""
    out = bytearray()
    out += struct.pack("<III", WEIGHTS_MAGIC, WEIGHTS_VERSION, len(params))
    for p in params:
        lh4, lx = p["wx"].shape
        lh = lh4 // 4
        out += struct.pack("<II", lx, lh)
        for name in ("wx", "wh", "bx", "bh"):
            arr = np.asarray(p[name], dtype="<f4")
            out += arr.tobytes(order="C")
    path.write_bytes(bytes(out))


def read_weights_bin(path: Path):
    """Inverse of write_weights_bin (round-trip testing)."""
    buf = path.read_bytes()
    magic, version, n_layers = struct.unpack_from("<III", buf, 0)
    assert magic == WEIGHTS_MAGIC and version == WEIGHTS_VERSION
    off = 12
    params = []
    for _ in range(n_layers):
        lx, lh = struct.unpack_from("<II", buf, off)
        off += 8
        layer = {}
        for name, shape in (
            ("wx", (4 * lh, lx)),
            ("wh", (4 * lh, lh)),
            ("bx", (4 * lh,)),
            ("bh", (4 * lh,)),
        ):
            count = int(np.prod(shape))
            arr = np.frombuffer(buf, dtype="<f4", count=count, offset=off)
            off += 4 * count
            layer[name] = jnp.asarray(arr.reshape(shape))
        params.append(layer)
    assert off == len(buf), "trailing bytes"
    return params
