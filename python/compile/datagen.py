"""Synthetic benign telemetry for training — the Python counterpart of
``rust/src/workload``: per-feature sinusoid mixtures (periods 8–64
timesteps, feature-correlated phases) plus small Gaussian noise. The
trained LSTM-AE therefore reconstructs exactly the distribution the Rust
workload generator streams at serving time.
"""

from __future__ import annotations

import numpy as np


LATENTS = 4  # shared with rust/src/workload: telemetry is low-rank


class Telemetry:
    """K latent sinusoids (periods 8–64 steps) mixed into F features.

    Low-rank structure is what makes the LSTM-AE's bottleneck learnable
    (and is how real fleet telemetry behaves: a few physical drivers,
    many correlated sensors)."""

    def __init__(self, features: int, seed: int, latents: int = LATENTS):
        rng = np.random.default_rng(seed)
        self.features = features
        self.latents = latents
        self.freq = 2.0 * np.pi / rng.uniform(8.0, 64.0, size=latents)
        self.phase = rng.uniform(0.0, 2.0 * np.pi, size=latents)
        # Mixing matrix, rows L1-normalized to keep |x| ≲ 0.9.
        m = rng.uniform(-1.0, 1.0, size=(features, latents))
        m = m / np.abs(m).sum(axis=1, keepdims=True)
        self.mix = m * rng.uniform(0.5, 0.9, size=(features, 1))
        self.noise_std = 0.02
        self.rng = rng

    def latent(self, steps: np.ndarray) -> np.ndarray:
        """(..., latents) latent trajectory at integer timesteps."""
        arg = self.freq * steps[..., None] + self.phase
        return np.sin(arg) + 0.15 * np.cos(2.0 * arg)

    def windows(self, n: int, t: int) -> np.ndarray:
        """(n, t, features) float32 batch of benign windows with random
        stream offsets."""
        starts = self.rng.integers(0, 100_000, size=n)
        steps = starts[:, None] + np.arange(t)[None, :]  # (n, t)
        z = self.latent(steps)  # (n, t, K)
        x = z @ self.mix.T
        x = x + self.noise_std * self.rng.standard_normal(x.shape)
        return x.astype(np.float32)

    def spec(self) -> dict:
        """Serializable family parameters — exported into artifacts/ so the
        Rust workload generator streams the *same* telemetry family the
        model was trained on (rust/src/workload TelemetryGen::from_spec)."""
        return {
            "features": self.features,
            "latents": self.latents,
            "freq": [float(v) for v in self.freq],
            "phase": [float(v) for v in self.phase],
            "mix": [float(v) for v in self.mix.reshape(-1)],
            "noise_std": self.noise_std,
        }
