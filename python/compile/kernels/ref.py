"""Pure-jnp correctness oracle for the Pallas LSTM-cell kernel.

Implements the paper's Figure-1 equations exactly, in f32, with the same
parameter layout the Rust golden model uses:

- ``wx``: (4·LH, LX), gate-major rows in order i, f, g, o
- ``wh``: (4·LH, LH)
- ``bx``, ``bh``: (4·LH,)

This file is the CORE correctness reference — the Pallas kernel
(``lstm_cell.py``), the scanned model (``model.py``), and (through the AOT
artifact + weights binary) the Rust f32 golden model are all tested
against it.
"""

from __future__ import annotations

import jax.numpy as jnp


def sigmoid(x):
    return jnp.reciprocal(1.0 + jnp.exp(-x))


def split_gates(pre, lh):
    """Split a (4·LH,) pre-activation vector into (i, f, g, o)."""
    return pre[0:lh], pre[lh : 2 * lh], pre[2 * lh : 3 * lh], pre[3 * lh : 4 * lh]


def lstm_cell_ref(params, h, c, x):
    """One LSTM timestep (paper Fig. 1). Returns (h_new, c_new)."""
    wx, wh, bx, bh = params["wx"], params["wh"], params["bx"], params["bh"]
    lh = h.shape[-1]
    pre = (wx @ x + bx) + (wh @ h + bh)
    i, f, g, o = split_gates(pre, lh)
    c_new = sigmoid(f) * c + sigmoid(i) * jnp.tanh(g)
    h_new = sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_layer_ref(params, xs):
    """Run one layer over a (T, LX) sequence with zero init; returns (T, LH).

    Plain Python loop — the unambiguous oracle for the scanned versions.
    """
    lh = params["wh"].shape[-1]
    h = jnp.zeros((lh,), dtype=xs.dtype)
    c = jnp.zeros((lh,), dtype=xs.dtype)
    outs = []
    for t in range(xs.shape[0]):
        h, c = lstm_cell_ref(params, h, c, xs[t])
        outs.append(h)
    return jnp.stack(outs)


def lstm_ae_ref(layer_params, xs):
    """Full autoencoder forward: stacked layers, loop oracle."""
    seq = xs
    for params in layer_params:
        seq = lstm_layer_ref(params, seq)
    return seq
