"""Q8.24 quantization grid + PWL sigmoid/tanh in jnp — the FPGA datapath
emulation, mirroring ``rust/src/fixed`` and ``rust/src/activations``
(same grid: breakpoints over [−8, 8], 128 segments, node values quantized
to Q8.24; hard saturation outside).

The hardware stores Q8.24 integers; here we emulate the *grid* in float:
``quantize(v) = round(v · 2²⁴) / 2²⁴`` with saturation at ±(2⁷ − ulp).
Computation is float64 inside the emulation so the only rounding is the
grid itself (f32 cannot represent all Q8.24 values above 1.0 exactly; the
Rust agreement test bounds that representation error).
"""

from __future__ import annotations

import jax

# The grid emulation needs true float64 (f32 cannot represent all Q8.24
# values above 1.0). Explicit dtypes keep the f32 model paths unchanged.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

FRAC_BITS = 24
SCALE = float(1 << FRAC_BITS)
Q_MAX = (2.0**31 - 1.0) / SCALE
Q_MIN = -(2.0**31) / SCALE

PWL_LO = -8.0
PWL_HI = 8.0
SEGMENTS = 128


def quantize(v):
    """Round to the Q8.24 grid with saturation (round-half-away like the
    Rust ``f64::round``)."""
    scaled = jnp.asarray(v, dtype=jnp.float64) * SCALE
    # jnp.round is round-half-even; emulate half-away like Rust's round():
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    return jnp.clip(rounded, -(2.0**31), 2.0**31 - 1.0) / SCALE


def _pwl_nodes(fn):
    import numpy as np

    xs = np.linspace(PWL_LO, PWL_HI, SEGMENTS + 1)
    ys = fn(xs)
    return jnp.asarray(np.asarray(quantize(ys)), dtype=jnp.float64)


def _pwl_eval(nodes, sat_lo, sat_hi, x):
    x64 = jnp.asarray(x, dtype=jnp.float64)
    width = (PWL_HI - PWL_LO) / SEGMENTS
    pos = (x64 - PWL_LO) / width
    k = jnp.clip(jnp.floor(pos), 0, SEGMENTS - 1).astype(jnp.int32)
    t = pos - k
    y0 = nodes[k]
    y1 = nodes[k + 1]
    y = y0 + (y1 - y0) * t
    y = jnp.where(x64 <= PWL_LO, sat_lo, y)
    y = jnp.where(x64 >= PWL_HI, sat_hi, y)
    return y


import numpy as _np

_SIG_NODES = _pwl_nodes(lambda x: 1.0 / (1.0 + _np.exp(-x)))
_TANH_NODES = _pwl_nodes(_np.tanh)


def pwl_sigmoid(x):
    """PWL sigmoid on the quantized node table (FPGA activation unit)."""
    return _pwl_eval(_SIG_NODES, 0.0, 1.0, x)


def pwl_tanh(x):
    return _pwl_eval(_TANH_NODES, -1.0, 1.0, x)


def lstm_cell_quant(params, h, c, x):
    """One LSTM timestep in the quantized datapath: weights/inputs/outputs
    on the Q8.24 grid, PWL activations, MVM accumulation in float64 with a
    single grid-rounding per MVM (matching the Rust wide-MAC discipline).
    """
    wx = quantize(params["wx"])
    wh = quantize(params["wh"])
    bx = quantize(params["bx"])
    bh = quantize(params["bh"])
    lh = h.shape[-1]
    x = quantize(x)
    h = quantize(h)
    c = quantize(c)
    mx = quantize(jnp.asarray(wx, jnp.float64) @ jnp.asarray(x, jnp.float64)) + bx
    mh = quantize(jnp.asarray(wh, jnp.float64) @ jnp.asarray(h, jnp.float64)) + bh
    pre = mx + mh
    i = pre[0:lh]
    f = pre[lh : 2 * lh]
    g = pre[2 * lh : 3 * lh]
    o = pre[3 * lh : 4 * lh]
    i = pwl_sigmoid(i)
    f = pwl_sigmoid(f)
    g = pwl_tanh(g)
    o = pwl_sigmoid(o)
    c_new = quantize(quantize(f * c) + quantize(i * g))
    h_new = quantize(o * pwl_tanh(c_new))
    return h_new, c_new
