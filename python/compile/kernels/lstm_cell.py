"""L1 — fused LSTM-cell Pallas kernel.

The paper's compute hot-spot is the pair of per-gate MVMs (MVM_X, MVM_H
in Fig. 2) followed by the activation/element-wise unit. On the FPGA these
are spatial units with configurable reuse factors; the TPU-style
re-expression (DESIGN.md §7 Hardware-Adaptation) is a **single fused
kernel** per (layer, timestep):

- the two MVMs become one matmul over the concatenated ``[x_t, h_{t−1}]``
  vector against the concatenated ``[Wx | Wh]`` weight block — the MXU
  analog of instantiating parallel multipliers;
- gate activations and the cell update run in the same kernel while the
  matmul tile is still in VMEM (the FPGA's FIFO-coupled activation unit);
- the reuse factor R maps to the row-tile size of the weight block: R = 1
  is a full 4·LH-row tile, higher R processes 4·LH/R rows per grid step
  (less parallelism, smaller live tile) — expressed via the grid +
  BlockSpec below.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ``ref.py`` and the timing
story lives in the Rust simulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(w_ref, b_ref, xh_ref, c_ref, h_out_ref, c_out_ref, *, lh: int):
    """Fused gate matmul + activations + element-wise cell update.

    Shapes:
      w_ref:  (4·LH, LX+LH)   concatenated [Wx | Wh], gate-major rows
      b_ref:  (4·LH,)         bx + bh (biases fused at trace time)
      xh_ref: (LX+LH,)        concatenated [x_t, h_{t−1}]
      c_ref:  (LH,)           previous cell state
    """
    w = w_ref[...]
    xh = xh_ref[...]
    pre = w @ xh + b_ref[...]
    i = jax.nn.sigmoid(pre[0:lh])
    f = jax.nn.sigmoid(pre[lh : 2 * lh])
    g = jnp.tanh(pre[2 * lh : 3 * lh])
    o = jax.nn.sigmoid(pre[3 * lh : 4 * lh])
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


def _lstm_kernel_tiled(w_ref, b_ref, xh_ref, c_ref, pre_ref, *, rows: int):
    """Row-tiled gate matmul (the reuse-factor analog): grid step k
    computes `rows` gate pre-activations. Activations are applied by the
    caller once all tiles land (they need gate-aligned slices)."""
    del rows
    pre_ref[...] = w_ref[...] @ xh_ref[...] + b_ref[...]
    _ = c_ref  # c is consumed by the element-wise stage in the caller


def lstm_cell_pallas(params, h, c, x, *, interpret: bool = True):
    """One LSTM timestep through the fused Pallas kernel.

    Numerically identical to ``ref.lstm_cell_ref`` (same op order, f32).
    """
    wx, wh, bx, bh = params["wx"], params["wh"], params["bx"], params["bh"]
    lh = h.shape[-1]
    w = jnp.concatenate([wx, wh], axis=1)
    b = bx + bh
    xh = jnp.concatenate([x, h])
    kernel = functools.partial(_lstm_kernel, lh=lh)
    h_new, c_new = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((lh,), x.dtype),
            jax.ShapeDtypeStruct((lh,), x.dtype),
        ),
        interpret=interpret,
    )(w, b, xh, c)
    return h_new, c_new


def lstm_cell_pallas_tiled(params, h, c, x, *, reuse: int = 1, interpret: bool = True):
    """Reuse-factor-tiled variant: the gate matmul runs over a grid of
    ``reuse`` row-tiles (4·LH/R rows each), mirroring how an FPGA MVM unit
    with reuse factor R time-multiplexes its multipliers. Functionally
    identical; exists to let the hardware-adaptation story be *tested*
    (tiled == fused == ref) and to bound the live VMEM tile.
    """
    wx, wh, bx, bh = params["wx"], params["wh"], params["bx"], params["bh"]
    lh = h.shape[-1]
    rows_total = 4 * lh
    if rows_total % reuse != 0:
        raise ValueError(f"reuse {reuse} must divide 4·LH = {rows_total}")
    rows = rows_total // reuse
    w = jnp.concatenate([wx, wh], axis=1)
    b = bx + bh
    xh = jnp.concatenate([x, h])
    kernel = functools.partial(_lstm_kernel_tiled, rows=rows)
    pre = pl.pallas_call(
        kernel,
        grid=(reuse,),
        in_specs=[
            pl.BlockSpec((rows, w.shape[1]), lambda k: (k, 0)),
            pl.BlockSpec((rows,), lambda k: (k,)),
            pl.BlockSpec(xh.shape, lambda k: (0,)),
            pl.BlockSpec(c.shape, lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((rows,), lambda k: (k,)),
        out_shape=jax.ShapeDtypeStruct((rows_total,), x.dtype),
        interpret=interpret,
    )(w, b, xh, c)
    i = jax.nn.sigmoid(pre[0:lh])
    f = jax.nn.sigmoid(pre[lh : 2 * lh])
    g = jnp.tanh(pre[2 * lh : 3 * lh])
    o = jax.nn.sigmoid(pre[3 * lh : 4 * lh])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def vmem_bytes(lx: int, lh: int, reuse: int = 1, dtype_bytes: int = 4) -> int:
    """Estimated live VMEM footprint of one kernel invocation (weights
    tile + vectors) — the §9 structural estimate recorded in DESIGN.md."""
    rows = 4 * lh // reuse
    cols = lx + lh
    return dtype_bytes * (rows * cols + rows + cols + 3 * lh)
