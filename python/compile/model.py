"""L2 — the LSTM-Autoencoder model in JAX: stacked LSTM layers scanned
over the sequence, calling the L1 Pallas kernel per (layer, timestep).

The AOT artifact (``aot.py``) lowers ``forward`` with trained weights
closed over as constants, so the Rust runtime receives a single
``(T, F) -> (T, F)`` computation with no parameter plumbing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.lstm_cell import lstm_cell_pallas
from .kernels.ref import lstm_cell_ref
from .topology import Topology


def init_params(topo: Topology, key):
    """PyTorch-style uniform(-1/sqrt(LH), 1/sqrt(LH)) init; returns a list
    of per-layer dicts with the wx/wh/bx/bh layout shared with Rust."""
    params = []
    for dims in topo.layers:
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        bound = 1.0 / jnp.sqrt(jnp.asarray(dims.lh, dtype=jnp.float32))
        u = lambda k, shape: jax.random.uniform(  # noqa: E731
            k, shape, jnp.float32, -bound, bound
        )
        params.append(
            {
                "wx": u(k1, (4 * dims.lh, dims.lx)),
                "wh": u(k2, (4 * dims.lh, dims.lh)),
                "bx": u(k3, (4 * dims.lh,)),
                "bh": u(k4, (4 * dims.lh,)),
            }
        )
    return params


def _layer_scan(params, xs, cell):
    """Scan one LSTM layer over (T, LX) -> (T, LH)."""
    lh = params["wh"].shape[-1]

    def step(carry, x):
        h, c = carry
        h2, c2 = cell(params, h, c, x)
        return (h2, c2), h2

    h0 = jnp.zeros((lh,), dtype=xs.dtype)
    c0 = jnp.zeros((lh,), dtype=xs.dtype)
    _, ys = jax.lax.scan(step, (h0, c0), xs)
    return ys


def forward(params, xs, *, use_pallas: bool = True, interpret: bool = True):
    """LSTM-AE reconstruction of a (T, F) window."""
    cell = (
        (lambda p, h, c, x: lstm_cell_pallas(p, h, c, x, interpret=interpret))
        if use_pallas
        else lstm_cell_ref
    )
    seq = xs
    for p in params:
        seq = _layer_scan(p, seq, cell)
    return seq


def forward_batched(params, xs, **kw):
    """(B, T, F) -> (B, T, F) via vmap (serving artifacts)."""
    return jax.vmap(lambda w: forward(params, w, **kw))(xs)


def reconstruction_mse(params, xs, **kw):
    """The anomaly score the server computes on the Rust side."""
    recon = forward(params, xs, **kw)
    return jnp.mean((recon - xs) ** 2)
