"""LSTM-AE-F{X}-D{Y} topology derivation — the Python mirror of
``rust/src/model/topology.rs`` (paper §4.1).

Layer i consumes ``LX_i`` features and produces ``LH_i``; the chain halves
feature sizes to the bottleneck and doubles back symmetrically, so the last
layer's hidden width equals the input width and the decoder output is the
reconstruction.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LayerDims:
    lx: int
    lh: int


@dataclass(frozen=True)
class Topology:
    name: str
    features: int
    depth: int
    layers: tuple[LayerDims, ...]

    @staticmethod
    def make(features: int, depth: int) -> "Topology":
        if depth <= 0 or depth % 2 != 0:
            raise ValueError(f"depth must be even and positive, got {depth}")
        half = depth // 2
        if features >> half == 0 or features % (1 << half) != 0:
            raise ValueError(f"features {features} incompatible with depth {depth}")
        chain = [features >> i for i in range(half + 1)]
        chain += [features >> i for i in reversed(range(half))]
        layers = tuple(LayerDims(chain[i], chain[i + 1]) for i in range(depth))
        return Topology(
            name=f"LSTM-AE-F{features}-D{depth}",
            features=features,
            depth=depth,
            layers=layers,
        )

    @staticmethod
    def from_name(name: str) -> "Topology":
        short = name.removeprefix("LSTM-AE-")
        f_part, _, d_part = short.partition("-D")
        if not f_part.startswith("F") or not d_part:
            raise ValueError(f"bad model name {name!r}")
        return Topology.make(int(f_part[1:]), int(d_part))

    def chain(self) -> list[int]:
        return [self.layers[0].lx] + [l.lh for l in self.layers]


PAPER_MODELS = ("LSTM-AE-F32-D2", "LSTM-AE-F64-D2", "LSTM-AE-F32-D6", "LSTM-AE-F64-D6")
