"""AOT compile path: train each paper model, bake the trained weights
into the HLO as constants, and emit HLO **text** artifacts the Rust
runtime loads via ``HloModuleProto::from_text_file``.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Outputs under --out:
  <model>_T<t>.hlo.txt       per (model, sequence length)
  weights_<model>.bin        Rust-loadable trained weights
  manifest.json              the runtime's index (written last: it is the
                             Makefile's freshness sentinel)
"""

from __future__ import annotations

import argparse
import functools
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from .topology import PAPER_MODELS, Topology

# Table 2/3 sequence lengths.
TIMESTEPS = (1, 2, 4, 6, 16, 64)
# Batch sizes for the vmapped serving artifacts.
SERVE_BATCHES = (4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path).

    ``print_large_constants=True`` is load-bearing: the trained weights are
    baked into the module as constants, and the default printer elides
    them as ``constant({...})``, which does not round-trip through the
    Rust-side text parser.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(params, t: int, features: int) -> str:
    """Lower forward(params, ·) at fixed (T, F) with params as constants."""
    fn = functools.partial(model_lib.forward, params, use_pallas=True, interpret=True)
    spec = jax.ShapeDtypeStruct((t, features), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_model_batched(params, b: int, t: int, features: int) -> str:
    """Lower the vmapped forward at fixed (B, T, F) — serving artifacts
    that amortize PJRT dispatch across a whole batch."""
    fn = functools.partial(
        model_lib.forward_batched, params, use_pallas=True, interpret=True
    )
    spec = jax.ShapeDtypeStruct((b, t, features), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def build_all(out_dir: Path, *, steps: int, timesteps=TIMESTEPS, models=PAPER_MODELS, log=print):
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"version": 1, "quant": {"word": 32, "frac_bits": 24}, "models": []}
    telemetry_written: set[int] = set()
    for name in models:
        topo = Topology.from_name(name)
        # Deeper models converge slower (longer credit-assignment path
        # through the bottleneck); give them proportionally more steps so
        # the benign-reconstruction floor is low enough for anomaly
        # separation (integration-tested on the Rust side).
        model_steps = steps if topo.depth <= 2 else steps * 4
        # Export the training telemetry family spec once per feature width
        # so the Rust workload generator can stream in-distribution data.
        tele_file = f"telemetry_F{topo.features}.json"
        if topo.features not in telemetry_written:
            spec = train_lib.telemetry_for(topo.features).spec()
            (out_dir / tele_file).write_text(json.dumps(spec) + "\n")
            telemetry_written.add(topo.features)
        log(f"[aot] training {name} ({model_steps} steps) ...")
        params, loss = train_lib.train_model(topo, steps=model_steps, log=log)
        weights_file = f"weights_{name}.bin"
        train_lib.write_weights_bin(out_dir / weights_file, params)
        hlo_map = {}
        for t in timesteps:
            log(f"[aot] lowering {name} T={t} ...")
            text = lower_model(params, t, topo.features)
            fname = f"{name}_T{t}.hlo.txt"
            (out_dir / fname).write_text(text)
            hlo_map[str(t)] = fname
        # Batched serving artifacts at the serving window length.
        serve_t = 16 if 16 in timesteps else max(timesteps)
        batch_map = {}
        for b in SERVE_BATCHES:
            log(f"[aot] lowering {name} T={serve_t} B={b} (serving) ...")
            text = lower_model_batched(params, b, serve_t, topo.features)
            fname = f"{name}_T{serve_t}_B{b}.hlo.txt"
            (out_dir / fname).write_text(text)
            batch_map[str(b)] = fname
        manifest["models"].append(
            {
                "name": name,
                "features": topo.features,
                "depth": topo.depth,
                "layers": topo.chain(),
                "weights": weights_file,
                "timesteps": list(timesteps),
                "hlo": hlo_map,
                "hlo_batch": {"t": serve_t, "sizes": batch_map},
                "telemetry": tele_file,
                "train_loss": loss,
            }
        )
    # Manifest last: it is the freshness sentinel for `make artifacts`.
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    log(f"[aot] wrote {out_dir / 'manifest.json'}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--steps", type=int, default=240, help="training steps per model")
    ap.add_argument(
        "--models",
        default=",".join(PAPER_MODELS),
        help="comma-separated model names",
    )
    ap.add_argument(
        "--timesteps",
        default=",".join(str(t) for t in TIMESTEPS),
        help="comma-separated sequence lengths",
    )
    args = ap.parse_args()
    build_all(
        Path(args.out),
        steps=args.steps,
        timesteps=tuple(int(t) for t in args.timesteps.split(",")),
        models=tuple(args.models.split(",")),
    )


if __name__ == "__main__":
    main()
