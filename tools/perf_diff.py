#!/usr/bin/env python3
"""Perf gate over BENCH_hotpath.json: fresh run vs committed baseline.

CI runs the hotpath bench (which rewrites BENCH_hotpath.json next to the
manifest), recovers the committed baseline via `git show HEAD:...`, and
calls this script with both. Rows whose name starts with the gated
prefix (default ``kernel ``) are the contract: any of them regressing
more than ``--max-regress`` in ns/iter fails the job. Everything else is
reported but advisory — end-to-end rows (server closed loops, autoscaler
scenarios, the score-cache replay) are too noisy on shared runners to
gate on.

The gate disarms itself, exit 0 with a notice, when the baseline is
absent, unparsable, marked ``"provisional": true``, or has no results —
so landing the tooling does not require timed numbers in the same PR.
Re-baselining is the `bench-rebaseline` workflow_dispatch job (one click
on the reference runner), or locally: run ``cargo bench --bench
hotpath`` and commit the rewritten JSON.

``--self-test`` runs the gate logic over synthetic baseline/fresh pairs
(pass, beyond-threshold regression, missing gated row, noisy advisory
row) and needs no files — CI executes it before trusting the real gate.

Stdlib only; no third-party imports.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_results(path: str) -> dict | None:
    """Return the results map, or None when the gate should disarm."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate disarmed: cannot read baseline {path}: {e}")
        return None
    if not isinstance(doc, dict):
        print(f"perf gate disarmed: {path} is not an object")
        return None
    if doc.get("provisional"):
        print(f"perf gate disarmed: {path} is marked provisional")
        return None
    results = doc.get("results")
    if not isinstance(results, dict) or not results:
        print(f"perf gate disarmed: {path} has no results")
        return None
    return results


def ns_per_iter(row) -> float | None:
    if isinstance(row, dict):
        v = row.get("ns_per_iter")
        if isinstance(v, (int, float)) and v > 0:
            return float(v)
    return None


def compare(base: dict, fresh: dict, max_regress: float, prefix: str) -> int:
    """Gate `fresh` against `base`; prints the table, returns an exit code.

    Disarms (0) when the runs share no rows; fails (1) when any gated row
    regresses beyond `max_regress` or is missing from the fresh run.
    """
    failures = []
    common = [n for n in fresh if n in base]
    if not common:
        print("perf gate disarmed: no rows in common with the baseline")
        return 0
    width = max(len(n) for n in common)
    print(f"{'row':<{width}}  {'base ns':>12}  {'fresh ns':>12}  {'delta':>8}  gate")
    for name in sorted(common):
        b, f = ns_per_iter(base[name]), ns_per_iter(fresh[name])
        if b is None or f is None:
            continue  # scenario rows (shed counts etc.) carry no timing
        delta = f / b - 1.0
        gated = name.startswith(prefix)
        verdict = "ok"
        if gated and delta > max_regress:
            verdict = "FAIL"
            failures.append((name, delta))
        print(
            f"{name:<{width}}  {b:>12.1f}  {f:>12.1f}  {delta:>+7.1%}  "
            f"{verdict if gated else '-'}"
        )

    missing = [n for n in base if n not in fresh and n.startswith(prefix)]
    for name in missing:
        print(f"{name}: gated row missing from fresh run")
        failures.append((name, float("inf")))

    if failures:
        print(
            f"\nperf gate FAILED: {len(failures)} gated row(s) regressed "
            f"beyond {max_regress:.0%}",
            file=sys.stderr,
        )
        return 1
    print(f"\nperf gate passed ({len(common)} rows compared)")
    return 0


def self_test() -> int:
    """Exercise the gate on synthetic pairs; exit 0 only if all behave."""
    kernel = {"kernel step_into 64x64 interleaved": {"ns_per_iter": 1000.0}}
    advisory = {"server closed-loop": {"ns_per_iter": 1000.0}}
    scalars = {"cache zipf fleet": {"batch_slots": 128.0}}

    def scaled(rows: dict, factor: float) -> dict:
        return {
            n: {k: v * factor if k == "ns_per_iter" else v for k, v in r.items()}
            for n, r in rows.items()
        }

    cases = [
        # (description, base, fresh, expected exit code)
        ("within threshold passes", kernel, scaled(kernel, 1.10), 0),
        ("beyond threshold fails", kernel, scaled(kernel, 1.20), 1),
        ("improvement passes", kernel, scaled(kernel, 0.50), 0),
        (
            "missing gated row fails",
            {**kernel, **advisory},
            dict(advisory),
            1,
        ),
        (
            "noisy advisory row stays advisory",
            {**kernel, **advisory},
            {**scaled(kernel, 1.0), **scaled(advisory, 2.0)},
            0,
        ),
        (
            "timing-free scalar rows are skipped",
            {**kernel, **scalars},
            {**scaled(kernel, 1.0), **scalars},
            0,
        ),
        ("disjoint runs disarm", kernel, advisory, 0),
    ]
    bad = 0
    for desc, base, fresh, want in cases:
        print(f"--- self-test: {desc} (expect exit {want})")
        got = compare(base, fresh, max_regress=0.15, prefix="kernel ")
        if got != want:
            print(f"SELF-TEST FAILED: {desc}: exit {got}, wanted {want}", file=sys.stderr)
            bad += 1
        print()
    if bad:
        print(f"perf gate self-test: {bad} case(s) FAILED", file=sys.stderr)
        return 1
    print(f"perf gate self-test passed ({len(cases)} cases)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "baseline",
        nargs="?",
        help="committed BENCH_hotpath.json (git show HEAD:...)",
    )
    ap.add_argument("fresh", nargs="?", help="BENCH_hotpath.json written by this run")
    ap.add_argument(
        "--max-regress",
        type=float,
        default=0.15,
        help="fractional ns/iter regression that fails a gated row (default 0.15)",
    )
    ap.add_argument(
        "--prefix",
        default="kernel ",
        help='row-name prefix that is gated (default "kernel "); other rows are advisory',
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the gate over synthetic baseline/fresh pairs and exit",
    )
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if args.baseline is None or args.fresh is None:
        ap.error("baseline and fresh are required unless --self-test")

    base = load_results(args.baseline)
    if base is None:
        return 0
    fresh = load_results(args.fresh)
    if fresh is None:
        print("perf gate error: fresh bench output unusable", file=sys.stderr)
        return 1
    return compare(base, fresh, args.max_regress, args.prefix)


if __name__ == "__main__":
    sys.exit(main())
